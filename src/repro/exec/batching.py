"""Batch planner: turn a scheduled memory program into a *batch schedule*.

MAGE's premise is that SC programs are oblivious — the instruction stream
is fixed before execution.  The same property that lets the planner
precompute a memory plan lets this pass precompute, once per plan, which
instructions can be dispatched together: within every window of compute
instructions between engine-level barriers (swap/NET directives, INPUT,
OUTPUT), instructions are levelled by operand-span dependencies and grouped
by (level, op, signature).  Each group is a set of *independent, identically
shaped* instructions the batched drivers (``exec.batched_gc`` /
``exec.batched_ckks``) execute as one gathered call instead of one Python
dispatch per instruction.

The result is a :class:`BatchSchedule` sidecar — a few flat int64 arrays —
keyed by ``plan_hash`` and cached through the serve daemon's
``ArtifactCache`` like any other plan artifact (see docs/ENGINE.md for the
on-disk format).

Correctness argument for the reorder: two instructions conflict iff any of
their operand spans overlap (RAW, WAW and WAR all force ordering, and the
level recurrence bumps past all three), so any two instructions on the same
level are independent and groups emitted level-ascending form a valid
topological order of the window.  Barriers (directives, INPUT, OUTPUT,
float-immediate rows) are never reordered — channel, RNG and I/O order is
exactly program order.  Operand spans in this DSL are exact allocation
spans, so spans are pairwise identical-or-disjoint; the builder *verifies*
that per window (one vectorized sweep) and falls back to scalar order for
any window where it does not hold.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from ..core.bytecode import (DEFAULT_CHUNK_INSTRS, DIRECTIVES, MAX_INS,
                             MAX_OUTS, _IMM_OFF, _IN_OFF, _OUT_OFF, Op,
                             Program, ProgramFile, iter_record_chunks,
                             unpack_heads)

SCHEDULE_VERSION = 1

#: ops whose side effects pin them to program order: engine directives
#: (swaps, NET traffic) and I/O against the input provider / output
#: channel.  FREE is *not* a barrier: the engine executes it as a no-op
#: (allocator bookkeeping lives in the planner), and any reuse of a freed
#: address shows up as an ordinary span conflict to the leveller — so
#: unbounded/virtual traces, which carry one FREE per dead value, still
#: form large batchable windows.
_BARRIER_OPS = frozenset(int(o) for o in DIRECTIVES) | {
    int(Op.INPUT), int(Op.OUTPUT)}

#: below this window size, a failed span-exactness check falls back to
#: scalar order instead of bisecting further
_MIN_SPLIT = 32


@dataclasses.dataclass
class BatchSchedule:
    """Precomputed execution order for one worker's memory program.

    Flat-array encoding (all int64), chunk-aligned to ``chunk_instrs`` so a
    streaming engine walks it with zero random access:

    * ``order``        — chunk-LOCAL row indices, concatenated group by
                         group over all chunks;
    * ``bounds``       — ``n_groups + 1`` offsets into ``order``;
    * ``group_op``     — per group, the shared opcode, or ``-1`` for a
                         scalar group (barriers and fallback windows) whose
                         rows run one by one in stored order;
    * ``chunk_groups`` — ``n_chunks + 1`` offsets into ``group_op``:
                         groups ``chunk_groups[c]:chunk_groups[c+1]``
                         belong to program chunk ``c``.

    Groups never cross chunk (or barrier) boundaries.  A group with
    ``group_op >= 0`` is *structurally* batchable — uniform op, immediates
    and span lengths, mutually independent; whether it actually runs
    batched is the driver's call (``batch_ops`` membership, group size).
    """

    chunk_instrs: int
    n_records: int
    order: np.ndarray
    bounds: np.ndarray
    group_op: np.ndarray
    chunk_groups: np.ndarray

    @property
    def n_groups(self) -> int:
        return len(self.group_op)

    @property
    def n_chunks(self) -> int:
        return len(self.chunk_groups) - 1

    def stats(self) -> dict:
        sizes = np.diff(self.bounds)
        batchable = self.group_op >= 0
        big = batchable & (sizes >= 2)
        return {
            "n_records": int(self.n_records),
            "n_chunks": int(self.n_chunks),
            "n_groups": int(self.n_groups),
            "batchable_groups": int(big.sum()),
            "batchable_instructions": int(sizes[big].sum()),
            "scalar_instructions": int(sizes[~big].sum()),
            "max_batch": int(sizes[batchable].max()) if batchable.any()
            else 0,
        }

    # -- persistence (the sidecar artifact format) ---------------------------

    def save(self, path: str | os.PathLike) -> None:
        with open(path, "wb") as f:
            np.savez(f,
                     version=np.array([SCHEDULE_VERSION], dtype=np.int64),
                     chunk_instrs=np.array([self.chunk_instrs],
                                           dtype=np.int64),
                     n_records=np.array([self.n_records], dtype=np.int64),
                     order=self.order.astype(np.int64),
                     bounds=self.bounds.astype(np.int64),
                     group_op=self.group_op.astype(np.int64),
                     chunk_groups=self.chunk_groups.astype(np.int64))

    @classmethod
    def load(cls, path: str | os.PathLike) -> "BatchSchedule":
        with np.load(path) as z:
            ver = int(z["version"][0])
            if ver != SCHEDULE_VERSION:
                raise ValueError(
                    f"batch schedule version {ver} != {SCHEDULE_VERSION}")
            return cls(chunk_instrs=int(z["chunk_instrs"][0]),
                       n_records=int(z["n_records"][0]),
                       order=z["order"], bounds=z["bounds"],
                       group_op=z["group_op"],
                       chunk_groups=z["chunk_groups"])

    def validate_for(self, prog: Program | ProgramFile) -> None:
        n = len(prog) if isinstance(prog, Program) else prog.num_records
        if n != self.n_records:
            raise ValueError(
                f"batch schedule covers {self.n_records} records but the "
                f"program has {n}; stale sidecar?")


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------


def _bisect_window(rec: np.ndarray, rows: np.ndarray, op: np.ndarray,
                   n_outs: np.ndarray, n_ins: np.ndarray, n_imm: np.ndarray,
                   ) -> list[tuple[int, list[int]]]:
    """Span-exactness failed for this window: rather than running the
    whole window scalar, bisect it — the two halves execute in program
    order, so each only has to satisfy the check locally.  One address
    reuse at a phase boundary then costs ~log2(window) small fallbacks
    instead of poisoning thousands of batchable rows."""
    if len(rows) < 2 * _MIN_SPLIT:
        return [(-1, [int(r) for r in rows])]
    h = len(rows) // 2
    return (_window_groups(rec, rows[:h], op, n_outs, n_ins, n_imm)
            + _window_groups(rec, rows[h:], op, n_outs, n_ins, n_imm))


def _window_groups(rec: np.ndarray, rows: np.ndarray, op: np.ndarray,
                   n_outs: np.ndarray, n_ins: np.ndarray, n_imm: np.ndarray,
                   ) -> list[tuple[int, list[int]]]:
    """Level + group one barrier-free window; returns ``(op, rows)`` pairs
    in a dependency-valid execution order (op == -1 => scalar fallback)."""
    # all operand spans of the window, one (addr, len) pair per slot
    slot_offs = [_OUT_OFF + 2 * j for j in range(MAX_OUTS)] + \
        [_IN_OFF + 2 * j for j in range(MAX_INS)]
    addr_cols = rec[np.ix_(rows, slot_offs)]
    len_cols = rec[np.ix_(rows, [o + 1 for o in slot_offs])]
    arity = np.concatenate([n_outs[rows, None] > np.arange(MAX_OUTS),
                            n_ins[rows, None] > np.arange(MAX_INS)], axis=1)
    live = arity & (len_cols > 0)
    addrs = addr_cols[live]
    lens = len_cols[live]
    if len(addrs) == 0:
        # no operands at all: nothing to batch, keep program order
        return [(-1, [int(r) for r in rows])]
    # spans must be pairwise identical-or-disjoint for span-keyed levelling
    order = np.lexsort((lens, addrs))
    a, ln = addrs[order], lens[order]
    same = a[1:] == a[:-1]
    if np.any(same & (ln[1:] != ln[:-1])):
        return _bisect_window(rec, rows, op, n_outs, n_ins, n_imm)
    keep = np.concatenate([[True], ~same])
    ua, ul = a[keep], ln[keep]
    if np.any(ua[1:] < ua[:-1] + ul[:-1]):
        return _bisect_window(rec, rows, op, n_outs, n_ins, n_imm)
    # span id per live slot; -1 for dead slots
    sid = np.full(addr_cols.shape, -1, dtype=np.int64)
    sid[live] = np.searchsorted(ua, addrs)
    wl = np.zeros(len(ua), dtype=np.int64)   # last writer level per span
    rl = np.zeros(len(ua), dtype=np.int64)   # max reader level per span
    sid_l = sid.tolist()
    no_l, ni_l = n_outs[rows].tolist(), n_ins[rows].tolist()
    groups: dict[tuple, list[int]] = {}
    rec_l = rec[rows].tolist()
    rows_l = rows.tolist()
    for k, r in enumerate(rows_l):
        srow = sid_l[k]
        no, ni = no_l[k], ni_l[k]
        lvl = 0
        for j in range(no):
            s = srow[j]
            if s >= 0:
                if wl[s] > lvl:
                    lvl = wl[s]
                if rl[s] > lvl:
                    lvl = rl[s]
        for j in range(ni):
            s = srow[MAX_OUTS + j]
            if s >= 0 and wl[s] > lvl:
                lvl = wl[s]
        lvl += 1
        for j in range(ni):
            s = srow[MAX_OUTS + j]
            if s >= 0 and rl[s] < lvl:
                rl[s] = lvl
        for j in range(no):
            s = srow[j]
            if s >= 0:
                wl[s] = lvl
        row = rec_l[k]
        key = (lvl, row[0],
               tuple(row[_OUT_OFF + 1 + 2 * j] for j in range(no)),
               tuple(row[_IN_OFF + 1 + 2 * j] for j in range(ni)),
               tuple(row[_IMM_OFF + j] for j in range(int(n_imm[r]))))
        groups.setdefault(key, []).append(r)
    # level-ascending, then first-row order: a valid topological order
    out = sorted(groups.items(), key=lambda kv: (kv[0][0], kv[1][0]))
    return [(int(k[1] & 0xFFFF), rws) for k, rws in out]


def _chunk_groups(start: int, rec: np.ndarray | None, m: int
                  ) -> list[tuple[int, list[int]]]:
    """Group one program chunk; rows are chunk-local."""
    if rec is None:
        # inexpressible in-memory chunk (wide arity / object immediates):
        # the record columns are unavailable, run it scalar
        return [(-1, list(range(m)))]
    op, n_outs, n_ins, n_imm = unpack_heads(rec[:, 0])
    fmask = (rec[:, 0] >> 28) & 0x3F
    barrier = np.isin(op, list(_BARRIER_OPS)) | (fmask != 0)
    free = (op == int(Op.FREE)) & ~barrier
    groups: list[tuple[int, list[int]]] = []
    bpos = np.flatnonzero(barrier)
    w0 = 0
    for b in list(bpos) + [m]:
        if b > w0:
            win = np.arange(w0, b, dtype=np.int64)
            # FREE rows are engine no-ops: hoist them out of the window
            # (they would otherwise fragment the span-conflict levelling
            # with dead allocator spans) and replay them after it
            fr = win[free[win]]
            if len(fr):
                win = win[~free[win]]
            if len(win):
                groups.extend(
                    _window_groups(rec, win, op, n_outs, n_ins, n_imm))
            if len(fr):
                groups.append((-1, [int(r) for r in fr]))
        if b < m:
            groups.append((-1, [int(b)]))
        w0 = b + 1
    # merge adjacent scalar groups (their rows stay in program order);
    # singleton "batchable" groups are demoted first — the engine would
    # run them scalar anyway, and merging shrinks the group stream
    merged: list[tuple[int, list[int]]] = []
    for g_op, rws in groups:
        if len(rws) < 2:
            g_op = -1
        if g_op == -1 and merged and merged[-1][0] == -1:
            merged[-1][1].extend(rws)
        else:
            merged.append((g_op, list(rws)))
    return merged


def build_batch_schedule(prog: Program | ProgramFile,
                         chunk_instrs: int | None = None) -> BatchSchedule:
    """One pass over the memory program's record chunks -> BatchSchedule.

    Runs on any phase (the barriers of an 'unbounded' run are just its
    NET/IO rows), streams ProgramFiles chunk by chunk, and is O(chunk)
    in memory.  Intended to run once per plan and be cached by
    ``plan_hash`` (see serve_daemon.cache.ArtifactCache.put_batch).
    """
    if chunk_instrs is None:
        chunk_instrs = DEFAULT_CHUNK_INSTRS
    order: list[np.ndarray] = []
    bounds = [0]
    group_op: list[int] = []
    chunk_groups = [0]
    n_records = 0
    for start, rec, instrs in iter_record_chunks(prog, chunk_instrs):
        m = rec.shape[0] if rec is not None else len(instrs)
        n_records += m
        for g_op, rws in _chunk_groups(start, rec, m):
            order.append(np.asarray(rws, dtype=np.int64))
            bounds.append(bounds[-1] + len(rws))
            group_op.append(g_op)
        chunk_groups.append(len(group_op))
    return BatchSchedule(
        chunk_instrs=chunk_instrs,
        n_records=n_records,
        order=(np.concatenate(order) if order
               else np.zeros(0, dtype=np.int64)),
        bounds=np.asarray(bounds, dtype=np.int64),
        group_op=np.asarray(group_op, dtype=np.int64),
        chunk_groups=np.asarray(chunk_groups, dtype=np.int64))
