"""Batched compiled execution backend (docs/ENGINE.md).

Three layers:

* :mod:`repro.exec.batching`    — plan-derived batch schedules (the
  sidecar artifact; built once per plan, cached by ``plan_hash``);
* :mod:`repro.exec.overlap`     — planned out-of-order issue schedules
  that hoist ``NET_SEND``s, defer ``NET_RECV`` completions and fill the
  WAN latency gap with independent local work (docs/OVERLAP.md);
* :mod:`repro.exec.base`        — the ``BatchedProtocolDriver`` contract
  and gather/scatter helpers;
* :mod:`repro.exec.batched_gc` / :mod:`repro.exec.batched_ckks` — the
  protocol batch kernels (numpy-vectorized on CPU, Pallas-compiled when a
  real XLA backend is present).

``Engine.run`` walks a :class:`~repro.exec.batching.BatchSchedule` when
one is attached and the driver implements ``execute_batch``; otherwise it
interprets instruction by instruction (the scalar reference path).
"""

from .base import BatchedProtocolDriver, make_batched
from .batched_ckks import BatchedCkksDriver
from .batched_gc import BatchedGCDriver, BatchedPlaintextDriver
from .batching import BatchSchedule, build_batch_schedule
from .overlap import OverlapSchedule, build_overlap_schedule

__all__ = [
    "BatchSchedule",
    "OverlapSchedule",
    "build_overlap_schedule",
    "BatchedCkksDriver",
    "BatchedGCDriver",
    "BatchedPlaintextDriver",
    "BatchedProtocolDriver",
    "build_batch_schedule",
    "make_batched",
]
