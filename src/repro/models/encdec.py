"""Encoder-decoder model (seamless-m4t backbone).

The audio frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (batch, frames, d_model) supplied by
input_specs(); the decoder is a standard causal transformer with
cross-attention into the encoder memory.  Training = teacher-forced
cross-entropy; decode shapes lower the DECODER step with the encoder memory
as an input.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention, attention_cross, attention_decode,
                     embed, init_attention, init_embed,
                     init_mlp, init_rmsnorm, mlp, rmsnorm, unembed)


def _init_enc_layer(rng, cfg):
    ks = jax.random.split(rng, 2)
    return {"ln1": init_rmsnorm(cfg.d_model, None),
            "attn": init_attention(ks[0], cfg),
            "ln2": init_rmsnorm(cfg.d_model, None),
            "mlp": init_mlp(ks[1], cfg)}


def _init_dec_layer(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {"ln1": init_rmsnorm(cfg.d_model, None),
            "attn": init_attention(ks[0], cfg),
            "lnx": init_rmsnorm(cfg.d_model, None),
            "xattn": init_attention(ks[1], cfg),
            "ln2": init_rmsnorm(cfg.d_model, None),
            "mlp": init_mlp(ks[2], cfg)}


def init_encdec(rng, cfg: ModelConfig) -> dict[str, Any]:
    ks = jax.random.split(rng, 4)
    enc = jax.vmap(lambda r: _init_enc_layer(r, cfg))(
        jax.random.split(ks[0], cfg.encoder_layers))
    dec = jax.vmap(lambda r: _init_dec_layer(r, cfg))(
        jax.random.split(ks[1], cfg.n_layers))
    return {"embed": init_embed(ks[2], cfg),
            "enc": enc, "dec": dec,
            "enc_norm": init_rmsnorm(cfg.d_model, None),
            "final_norm": init_rmsnorm(cfg.d_model, None)}


def encode(params, frames: jnp.ndarray, cfg: ModelConfig,
           remat: bool = True) -> jnp.ndarray:
    """frames: (b, s, d) precomputed frame embeddings (frontend stub)."""
    x = frames

    def body(h, p):
        h = h + attention(p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps),
                          cfg, causal=False)
        h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_block(p, h, memory, cfg):
    h = h + attention(p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg)
    h = h + attention_cross(p["xattn"], rmsnorm(p["lnx"], h, cfg.norm_eps),
                            memory, cfg)
    h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
    return h


def encdec_forward(params, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ModelConfig, remat: bool = True) -> jnp.ndarray:
    memory = encode(params, frames, cfg, remat)
    x = embed(params["embed"], tokens)

    def body(h, p):
        return _dec_block(p, h, memory, cfg), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x)


def encdec_prefill(params, frames: jnp.ndarray, tokens: jnp.ndarray,
                   cfg: ModelConfig) -> jnp.ndarray:
    """Prompt processing for serving: unembed ONLY the last position
    (full-seq logits are a training artifact; at 32k x 256k vocab they
    would dominate memory)."""
    memory = encode(params, frames, cfg, remat=False)
    x = embed(params["embed"], tokens)

    def body(h, p):
        return _dec_block(p, h, memory, cfg), None
    x, _ = jax.lax.scan(body, x, params["dec"])
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    return unembed(params["embed"], x)


def encdec_loss(params, frames, tokens, cfg: ModelConfig):
    logits = encdec_forward(params, frames, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss}


def encdec_init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def encdec_decode(params, token: jnp.ndarray, memory: jnp.ndarray, caches,
                  cache_len: jnp.ndarray, cfg: ModelConfig):
    """One decoder step against encoder memory + self-attention cache."""
    x = embed(params["embed"], token)

    def body(h, pc):
        p, k, v = pc
        out, (k2, v2) = attention_decode(
            p["attn"], rmsnorm(p["ln1"], h, cfg.norm_eps), cfg, (k, v),
            cache_len)
        h = h + out
        h = h + attention_cross(p["xattn"],
                                rmsnorm(p["lnx"], h, cfg.norm_eps),
                                memory, cfg)
        h = h + mlp(p["mlp"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, (k2, v2)

    x, (k2, v2) = jax.lax.scan(body, x, (params["dec"],) + caches)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), (k2, v2)
