"""Mixture-of-Experts MLP: top-k routing with capacity (GShard-style einsum
dispatch at group granularity), optional shared experts (DeepSeekMoE).

Experts are sharded over the 'experts' logical axis (EP on the model mesh
axis).  Group size bounds the dispatch/combine tensor to
(group, E, capacity), keeping memory modest while staying fully static for
the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import Params, _dtype, _init, mlp


def init_moe(rng, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 5)
    p = {
        "router": _init(ks[0], (d, e), d ** -0.5, jnp.float32),
        "experts_gate": _init(ks[1], (e, d, f), d ** -0.5, dt),
        "experts_up": _init(ks[2], (e, d, f), d ** -0.5, dt),
        "experts_down": _init(ks[3], (e, f, d), f ** -0.5, dt),
    }
    if cfg.n_shared_experts:
        sub = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _init(sub[0], (d, f * cfg.n_shared_experts),
                            d ** -0.5, dt),
            "w_up": _init(sub[1], (d, f * cfg.n_shared_experts),
                          d ** -0.5, dt),
            "w_down": _init(sub[2], (f * cfg.n_shared_experts, d),
                            f ** -0.5, dt),
        }
    return p


def moe_mlp(p: Params, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (b, s, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    # largest divisor of b*s not exceeding the configured group size
    # (seq is often 4095 after the next-token shift, so don't assume 2^k)
    g = min(cfg.router_group_size, b * s)
    while (b * s) % g:
        g -= 1
    n_groups = (b * s) // g
    cap = max(int(g * k * cfg.capacity_factor / e), 1)

    xt = x.reshape(n_groups, g, d)
    logits = (xt.astype(jnp.float32) @ p["router"])           # (G, g, e)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection
    topv, topi = jax.lax.top_k(probs, k)                       # (G, g, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

    # position of each (token, choice) in its expert's queue
    sel = jax.nn.one_hot(topi, e, dtype=jnp.float32)           # (G, g, k, e)
    flat = sel.reshape(n_groups, g * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(n_groups, g, k, e)
    pos = jnp.sum(pos * sel, axis=-1)                          # (G, g, k)
    keep = pos < cap
    weights = topv * keep                                      # dropped = 0

    # dispatch/combine tensors: (G, g, e, cap)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32)       # (G, g, k, cap)
    disp = jnp.einsum("Ggke,Ggkc->Ggec", sel, pos_oh * keep[..., None])
    comb = jnp.einsum("Ggke,Ggkc,Ggk->Ggec", sel, pos_oh, weights)
    disp = shard(disp, "batch", None, "experts", None)
    comb = shard(comb, "batch", None, "experts", None)

    xin = jnp.einsum("Ggd,Ggec->Gecd", xt.astype(jnp.float32), disp)
    xin = shard(xin.astype(x.dtype), "batch", "experts", None, None)

    gate = jnp.einsum("Gecd,edf->Gecf", xin, p["experts_gate"])
    up = jnp.einsum("Gecd,edf->Gecf", xin, p["experts_up"])
    act = shard(jax.nn.silu(gate) * up, "batch", "experts", None, None)
    eout = jnp.einsum("Gecf,efd->Gecd", act, p["experts_down"])

    out = jnp.einsum("Gecd,Ggec->Ggd", eout.astype(jnp.float32), comb)
    out = out.reshape(b, s, d).astype(x.dtype)

    if "shared" in p:
        out = out + mlp(p["shared"], x)

    # load-balancing aux loss (Switch): e * sum_e f_e * P_e
    me = jnp.mean(sel.sum(axis=2).reshape(-1, e), axis=0)
    pe = jnp.mean(probs.reshape(-1, e), axis=0)
    aux = e * jnp.sum(me * pe)
    return shard(out, "batch", "seq", None), aux
