"""xLSTM blocks ([arXiv:2405.04517]): mLSTM (matrix memory, parallel
quadratic form for training, O(1) recurrence for decode) and sLSTM (scalar
memory, sequential scan with exponential gating)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import Params, _dtype, _init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "mq": _init(ks[0], (d, d), d ** -0.5, dt),
        "mk": _init(ks[1], (d, d), d ** -0.5, dt),
        "mv": _init(ks[2], (d, d), d ** -0.5, dt),
        "w_i": _init(ks[3], (d, nh), d ** -0.5, jnp.float32),
        "w_f": _init(ks[4], (d, nh), d ** -0.5, jnp.float32),
        "f_bias": jnp.full((nh,), 3.0, jnp.float32),  # forget ~ 1 at init
        "m_out": _init(ks[5], (d, d), d ** -0.5, dt),
        "norm_scale": jnp.ones((d,), jnp.float32),
    }


def _mlstm_qkv(p, x, cfg):
    b, s, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    q = shard((x @ p["mq"]).reshape(b, s, nh, hd), "batch", "seq", "heads",
              None)
    k = shard((x @ p["mk"]).reshape(b, s, nh, hd), "batch", "seq", "heads",
              None)
    v = shard((x @ p["mv"]).reshape(b, s, nh, hd), "batch", "seq", "heads",
              None)
    logi = (x.astype(jnp.float32) @ p["w_i"])                  # (b, s, nh)
    logf = -jax.nn.softplus(-(x.astype(jnp.float32) @ p["w_f"]
                              + p["f_bias"]))                  # log sigmoid
    return q, k, v, logi, logf


# Chunkwise form above this sequence length: at 4k the quadratic D-matrix
# costs 8x the chunkwise form's flops (S/chunk = 4096/512), and mLSTM's
# recurrence makes them mathematically equivalent — §Perf hillclimb #3
# lowered this from 4096 (prefill-only) to cover train_4k too.
MLSTM_CHUNK_THRESHOLD = 2048
MLSTM_CHUNK = 512


def mlstm_block_chunked(p: Params, x: jnp.ndarray, cfg,
                        chunk: int = MLSTM_CHUNK) -> jnp.ndarray:
    """Chunkwise-parallel mLSTM: O(s*chunk) memory instead of O(s^2).

    Within-chunk quadratic D-matrix + inter-chunk (C, n, M) recurrent state
    with running max-stabilizers (the xLSTM chunkwise formulation)."""
    b, s0, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    pad = (-s0) % chunk
    q, k, v, logi, logf = _mlstm_qkv(p, x, cfg)
    if pad:
        zl = jnp.zeros((b, pad, nh, hd), q.dtype)
        q = jnp.concatenate([q, zl], axis=1)
        k = jnp.concatenate([k, zl], axis=1)
        v = jnp.concatenate([v, zl], axis=1)
        logi = jnp.concatenate(
            [logi, jnp.full((b, pad, nh), -1e30)], axis=1)
        logf = jnp.concatenate(
            [logf, jnp.zeros((b, pad, nh))], axis=1)
    s = s0 + pad
    nc = s // chunk
    qc = (q.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
          * hd ** -0.5)
    kc = k.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
    vc = v.reshape(b, nc, chunk, nh, hd).astype(jnp.float32)
    lic = logi.reshape(b, nc, chunk, nh)
    lfc = logf.reshape(b, nc, chunk, nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(carry, inp):
        C, n, M = carry                       # (b,nh,hd,hd),(b,nh,hd),(b,nh)
        qi, ki, vi, li, lf = inp
        g = jnp.cumsum(lf, axis=1)            # (b, Q, nh)
        bmat = (g[:, :, None, :] - g[:, None, :, :]
                + li[:, None, :, :])          # (b, i, j, nh)
        bmat = jnp.where(tri[None, :, :, None], bmat, -1e30)
        s_inter = g + M[:, None, :]           # (b, Q, nh)
        m = jnp.maximum(jnp.max(bmat, axis=2), s_inter)   # (b, Q, nh)
        dexp = jnp.exp(bmat - m[:, :, None, :])
        scores = jnp.einsum("bihd,bjhd->bijh", qi, ki)
        w = scores * dexp
        inter_scale = jnp.exp(s_inter - m)                # (b, Q, nh)
        num = (jnp.einsum("bijh,bjhd->bihd", w, vi)
               + inter_scale[..., None]
               * jnp.einsum("bihd,bhde->bihe", qi, C))
        den_dot = (jnp.sum(w, axis=2)
                   + inter_scale * jnp.einsum("bihd,bhd->bih", qi, n))
        den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m))
        y = num / den[..., None]
        # state update
        tot = g[:, -1]                                     # (b, nh)
        decay_j = tot[:, None, :] - g + li                 # (b, Q, nh)
        M_new = jnp.maximum(tot + M, jnp.max(decay_j, axis=1))
        carry_scale = jnp.exp(tot + M - M_new)
        wj = jnp.exp(decay_j - M_new[:, None, :])
        C_new = (carry_scale[:, :, None, None] * C
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", wj, ki, vi))
        n_new = (carry_scale[..., None] * n
                 + jnp.einsum("bjh,bjhd->bhd", wj, ki))
        return (C_new, n_new, M_new), y

    C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    M0 = jnp.full((b, nh), -1e30, jnp.float32)
    xs = (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
          lic.swapaxes(0, 1), lfc.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, (C0, n0, M0), xs)
    y = ys.swapaxes(0, 1).reshape(b, s, d)[:, :s0]
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return shard(y @ p["m_out"], "batch", "seq", None)


def mlstm_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Parallel (stabilized) quadratic form; x: (b, s, d)."""
    b, s, d = x.shape
    if s >= MLSTM_CHUNK_THRESHOLD:
        return mlstm_block_chunked(p, x, cfg)
    nh = cfg.n_heads
    hd = d // nh
    q, k, v, logi, logf = _mlstm_qkv(p, x, cfg)
    cumf = jnp.cumsum(logf, axis=1)                            # (b, s, nh)
    # log D_ij = cumf_i - cumf_j + logi_j  (i >= j)
    dmat = (cumf[:, :, None, :] - cumf[:, None, :, :]
            + logi[:, None, :, :])                             # (b,si,sj,nh)
    mask = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)                   # row stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bihd,bjhd->bijh", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * hd ** -0.5
    w = scores * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2, keepdims=True)),
                       jnp.exp(-m))                            # (b,si,1,nh)
    y = jnp.einsum("bijh,bjhd->bihd", w / norm, v.astype(jnp.float32))
    y = y.reshape(b, s, d)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return shard(y @ p["m_out"], "batch", "seq", None)


def mlstm_init_state(cfg, batch: int):
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_decode(p: Params, x: jnp.ndarray, cfg, state):
    """One-token recurrence; x: (b, 1, d)."""
    b, _, d = x.shape
    nh = cfg.n_heads
    hd = d // nh
    q, k, v, logi, logf = _mlstm_qkv(p, x, cfg)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    logi, logf = logi[:, 0], logf[:, 0]                        # (b, nh)
    m_new = jnp.maximum(logf + state["m"], logi)
    a = jnp.exp(logf + state["m"] - m_new)
    bgt = jnp.exp(logi - m_new)
    C = state["C"] * a[..., None, None] + bgt[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    n = state["n"] * a[..., None] + bgt[..., None] * k.astype(jnp.float32)
    qf = q.astype(jnp.float32) * hd ** -0.5
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(b, 1, d)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return y @ p["m_out"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg) -> Params:
    d = cfg.d_model
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 2)
    return {
        "w_x": _init(ks[0], (d, 4 * d), d ** -0.5, jnp.float32),
        "w_h": _init(ks[1], (d, 4 * d), d ** -0.5, jnp.float32),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "norm_scale": jnp.ones((d,), jnp.float32),
    }


def slstm_init_state(cfg, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30)}


def _slstm_step(p, state, xt):
    """xt: (b, d) f32; exponential-gated scalar LSTM cell."""
    pre = xt @ p["w_x"] + state["h"] @ p["w_h"] + p["bias"]
    d = xt.shape[-1]
    zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
    logi = zi
    logf = -jax.nn.softplus(-zf)
    m_new = jnp.maximum(logf + state["m"], logi)
    a = jnp.exp(logf + state["m"] - m_new)
    bgt = jnp.exp(logi - m_new)
    c = state["c"] * a + bgt * jnp.tanh(zz)
    n = state["n"] * a + bgt
    h = jax.nn.sigmoid(zo) * c / jnp.maximum(n, 1.0)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Sequential scan over time; x: (b, s, d)."""
    b, s, d = x.shape

    def step(state, xt):
        new = _slstm_step(p, state, xt)
        return new, new["h"]

    _, hs = jax.lax.scan(step, slstm_init_state(cfg, b),
                         x.astype(jnp.float32).swapaxes(0, 1))
    y = hs.swapaxes(0, 1)
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return shard(y, "batch", "seq", None)


def slstm_decode(p: Params, x: jnp.ndarray, cfg, state):
    new = _slstm_step(p, state, x[:, 0].astype(jnp.float32))
    y = new["h"][:, None]
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return y, new
