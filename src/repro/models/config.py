"""Unified model configuration covering all assigned architecture families:
dense / MoE / SSM (Mamba2, xLSTM) / hybrid / encoder-decoder / VLM-audio
backbones.  One dataclass so that configs/<arch>.py stay declarative."""

from __future__ import annotations

import dataclasses
import enum


class BlockKind(str, enum.Enum):
    ATTN = "attn"              # self-attention + MLP block
    MAMBA2 = "mamba2"          # SSD block
    MLSTM = "mlstm"            # xLSTM matrix-memory block
    SLSTM = "slstm"            # xLSTM scalar-memory block
    SHARED_ATTN = "shared_attn"  # zamba2-style shared transformer block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False        # qwen2
    rope_theta: float = 10_000.0
    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_group_size: int = 1024
    # --- SSM / recurrent ---
    ssm_state: int = 0            # Mamba2 state dim N
    ssm_head_dim: int = 64        # Mamba2 P
    ssm_expand: int = 2
    ssm_chunk: int = 128
    slstm_every: int = 0          # xLSTM: every k-th block is sLSTM
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0    # apply the shared attn block every k layers
    # --- enc-dec (seamless) ---
    encoder_layers: int = 0       # >0 -> encoder-decoder model
    # --- modality stub ---
    frontend: str = "none"        # none | audio_frames | vq_image (stub note)
    # --- training defaults ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- serving ---
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (quantized KV)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        """True if no quadratic-attention path exists (long_500k eligible
        without caveats)."""
        return self.family == "ssm" and self.slstm_every >= 0 and \
            self.shared_attn_every == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode: SSM/hybrid/linear-recurrent."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for MODEL_FLOPS."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * self.d_ff
        if self.moe:
            expert_mlp = 3 * d * self.d_ff
            mlp = (self.n_experts + self.n_shared_experts) * expert_mlp \
                + d * self.n_experts
        d_in = self.ssm_expand * d
        nh = max(d_in // self.ssm_head_dim, 1)
        mamba = d * (2 * d_in + 2 * self.ssm_state + nh) + d_in * d \
            + 2 * d_in
        lstm_m = 4 * d * d  # qkv + out (mLSTM approx)
        per_layer = {
            "dense": attn + mlp, "moe": attn + mlp, "vlm": attn + mlp,
            "audio": attn + mlp,
            "ssm": lstm_m + mlp if self.slstm_every else mamba + mlp,
            "hybrid": mamba,
        }[self.family]
        total += self.n_layers * per_layer
        if self.shared_attn_every:
            total += attn + mlp  # one shared block
        if self.is_encdec:
            total += self.encoder_layers * (attn + mlp) \
                + self.n_layers * (attn // 2)  # cross-attention
        return int(total)

    def active_param_estimate(self) -> int:
        """Active params per token (MoE: only routed top_k + shared)."""
        if not self.moe:
            return self.param_count_estimate()
        d = self.d_model
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp_active = 3 * d * self.d_ff * (self.top_k + self.n_shared_experts)
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += self.n_layers * (attn + mlp_active + d * self.n_experts)
        return int(total)
