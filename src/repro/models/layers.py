"""Core transformer layers: RMSNorm, RoPE, GQA attention (train / prefill /
decode-with-cache), SwiGLU MLP.  Functional style: params are plain dicts;
init_* return param trees; apply functions are jit/scan-friendly.

Activation sharding constraints use the logical axes of
distributed/sharding.py so the same model code runs under any rule set.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import shard

Params = dict[str, Any]


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def _init(rng, shape, scale: float, dtype) -> jnp.ndarray:
    return (jax.random.normal(rng, shape, dtype=jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norm
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta), dtype=jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional qkv bias)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg) -> Params:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    s = d ** -0.5
    p = {
        "wq": _init(ks[0], (d, nh * hd), s, dt),
        "wk": _init(ks[1], (d, nkv * hd), s, dt),
        "wv": _init(ks[2], (d, nkv * hd), s, dt),
        "wo": _init(ks[3], (nh * hd, d), (nh * hd) ** -0.5, dt),
    }
    if cfg.qkv_bias:
        p["q_bias"] = jnp.zeros((nh * hd,), dt)
        p["k_bias"] = jnp.zeros((nkv * hd,), dt)
        p["v_bias"] = jnp.zeros((nkv * hd,), dt)
    return p


def _qkv(p: Params, x: jnp.ndarray, cfg, positions) -> tuple:
    b, s, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["q_bias"]
        k = k + p["k_bias"]
        v = v + p["v_bias"]
    q = shard(q.reshape(b, s, nh, hd), "batch", "seq", "heads", None)
    k = shard(k.reshape(b, s, nkv, hd), "batch", "seq", "kv_heads", None)
    v = shard(v.reshape(b, s, nkv, hd), "batch", "seq", "kv_heads", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


CHUNKED_SDPA_THRESHOLD = 8192   # use flash-style blocking above this seq
SDPA_Q_BLOCK = 512
SDPA_KV_BLOCK = 1024


def _sdpa_chunked(q, k, v, cfg, causal_offset: int | None,
                  q_block: int = SDPA_Q_BLOCK,
                  kv_block: int = SDPA_KV_BLOCK) -> jnp.ndarray:
    """Flash-style online-softmax attention: never materializes (sq, skv).

    Memory per step: one (b, nkv, group, q_block, kv_block) tile — the jnp
    analogue of the VMEM tiling a fused TPU kernel would use."""
    b, sq0, nh, hd = q.shape
    skv0, nkv = k.shape[1], k.shape[2]
    group = nh // nkv
    qpad = (-sq0) % q_block
    kpad = (-skv0) % kv_block
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    sq, skv = sq0 + qpad, skv0 + kpad
    scale = hd ** -0.5
    nq, nk = sq // q_block, skv // kv_block
    qb = q.reshape(b, nq, q_block, nkv, group, hd).astype(jnp.float32)
    kb = k.reshape(b, nk, kv_block, nkv, hd).astype(jnp.float32)
    vb = v.reshape(b, nk, kv_block, nkv, hd).astype(jnp.float32)

    def q_step(_, iq):
        qi = qb[:, iq] * scale                      # (b, qb, nkv, g, hd)
        m0 = jnp.full((b, nkv, group, q_block), -1e30, jnp.float32)
        l0 = jnp.zeros((b, nkv, group, q_block), jnp.float32)
        a0 = jnp.zeros((b, nkv, group, q_block, hd), jnp.float32)

        def kv_step(carry, ik):
            m, l, acc = carry
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kb[:, ik])
            cols = ik * kv_block + jnp.arange(kv_block)[None, :]
            if causal_offset is not None:
                rows = iq * q_block + jnp.arange(q_block)[:, None] \
                    + causal_offset
                keep = (cols <= rows) & (cols < skv0)
            else:
                keep = jnp.broadcast_to(cols < skv0, (q_block, kv_block))
            s = jnp.where(keep[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb[:, ik])
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)    # (b, qb, nkv, g, hd)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(nq))
    # blocks: (nq, b, q_block, nkv, group, hd)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, nh * hd)
    return out[:, :sq0].astype(q.dtype)


def _sdpa(q, k, v, cfg, causal_offset: int | None) -> jnp.ndarray:
    """q: (b, sq, nh, hd); k/v: (b, skv, nkv, hd).  causal_offset = skv - sq
    for causal masking; None = no mask (full)."""
    b, sq, nh, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    if sq >= CHUNKED_SDPA_THRESHOLD:
        return _sdpa_chunked(q, k, v, cfg, causal_offset)
    group = nh // nkv
    qg = q.reshape(b, sq, nkv, group, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = shard(scores, "batch", "kv_heads", None, "scores_q", None)
    if causal_offset is not None:
        iq = jnp.arange(sq)[:, None] + causal_offset
        ik = jnp.arange(skv)[None, :]
        mask = ik <= iq
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, nh * hd).astype(q.dtype)


def attention(p: Params, x: jnp.ndarray, cfg,
              positions: jnp.ndarray | None = None,
              causal: bool = True) -> jnp.ndarray:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    out = _sdpa(q, k, v, cfg, 0 if causal else None)
    out = out @ p["wo"]
    return shard(out, "batch", "seq", None)


def attention_prefill(p: Params, x: jnp.ndarray, cfg, positions=None):
    """Returns (out, (k_cache, v_cache)) for subsequent decode."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    q, k, v = _qkv(p, x, cfg, positions)
    out = _sdpa(q, k, v, cfg, 0) @ p["wo"]
    return shard(out, "batch", "seq", None), (k, v)


def _quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(token, head) int8 quantization: x (b, s, h, d) ->
    (q int8, scale f16 (b, s, h))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def attention_decode(p: Params, x: jnp.ndarray, cfg, cache, cache_len):
    """One new token against a (padded) KV cache.

    x: (b, 1, d); cache: (k, v) each (b, max_seq, nkv, hd) — or, with
    cfg.kv_cache_dtype == 'int8', (k_q, v_q, k_scale, v_scale) with int8
    payloads and per-(token, head) f16 scales (halves the decode HBM
    traffic; §Perf hillclimb #2).  cache_len (b,) valid entries.
    Returns (out, updated cache)."""
    b = x.shape[0]
    quant = len(cache) == 4
    positions = cache_len[:, None]
    q, k_new, v_new = _qkv(p, x, cfg, positions)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = nh // nkv
    if quant:
        k_cache, v_cache, k_sc, v_sc = cache
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        idx4 = cache_len[:, None, None, None]
        idx3 = cache_len[:, None, None]
        oh4 = (jnp.arange(k_cache.shape[1])[None, :, None, None] == idx4)
        oh3 = (jnp.arange(k_cache.shape[1])[None, :, None] == idx3)
        k_cache = jnp.where(oh4, kq, k_cache)
        v_cache = jnp.where(oh4, vq, v_cache)
        k_sc = jnp.where(oh3, ks, k_sc)
        v_sc = jnp.where(oh3, vs, v_sc)
        k_eff = (k_cache.astype(jnp.float32)
                 * k_sc.astype(jnp.float32)[..., None])
        v_eff = (v_cache.astype(jnp.float32)
                 * v_sc.astype(jnp.float32)[..., None])
        new_cache = (k_cache, v_cache, k_sc, v_sc)
    else:
        k_cache, v_cache = cache
        idx = cache_len[:, None, None, None]
        onehot = (jnp.arange(k_cache.shape[1])[None, :, None, None] == idx)
        k_cache = jnp.where(onehot, k_new.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(onehot, v_new.astype(v_cache.dtype), v_cache)
        k_eff = k_cache.astype(jnp.float32)
        v_eff = v_cache.astype(jnp.float32)
        new_cache = (k_cache, v_cache)
    skv = k_cache.shape[1]
    qg = q.reshape(b, 1, nkv, group, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k_eff) * hd ** -0.5
    valid = (jnp.arange(skv)[None, :] <= cache_len[:, None])
    scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v_eff)
    out = out.reshape(b, 1, nh * hd).astype(x.dtype) @ p["wo"]
    return out, new_cache


def attention_cross(p: Params, x: jnp.ndarray, memory: jnp.ndarray, cfg):
    """Cross-attention (decoder -> encoder memory), no mask, no rope."""
    b, s, _ = x.shape
    sm = memory.shape[1]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, nh, hd)
    k = (memory @ p["wk"]).reshape(b, sm, nkv, hd)
    v = (memory @ p["wv"]).reshape(b, sm, nkv, hd)
    out = _sdpa(q, k, v, cfg, None) @ p["wo"]
    return shard(out, "batch", "seq", None)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(rng, cfg, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _init(ks[0], (d, f), d ** -0.5, dt),
        "w_up": _init(ks[1], (d, f), d ** -0.5, dt),
        "w_down": _init(ks[2], (f, d), f ** -0.5, dt),
    }


def mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = shard(x @ p["w_gate"], "batch", "seq", "ff")
    u = shard(x @ p["w_up"], "batch", "seq", "ff")
    return shard((jax.nn.silu(g) * u) @ p["w_down"], "batch", "seq", None)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def init_embed(rng, cfg) -> Params:
    dt = _dtype(cfg)
    p = {"embed": _init(rng, (cfg.vocab_size, cfg.d_model), 0.02, dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(jax.random.fold_in(rng, 1),
                             (cfg.d_model, cfg.vocab_size),
                             cfg.d_model ** -0.5, dt)
    return p


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return shard(jnp.take(p["embed"], tokens, axis=0),
                 "batch", "seq", None)


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    if "lm_head" in p:
        logits = x @ p["lm_head"]
    else:
        logits = x @ p["embed"].T
    return shard(logits.astype(jnp.float32), "batch", "seq", "vocab")
