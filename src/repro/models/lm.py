"""Unified causal LM: init / train forward / prefill / decode for every
assigned decoder-only architecture (dense, MoE, Mamba2-hybrid, xLSTM, VLM
backbone).  Layer stacks are scan-grouped (blocks.grouped layouts) so the
lowered HLO stays compact on 512-device meshes; per-layer remat is applied
in train mode.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .blocks import (block_decode, block_init_cache, block_prefill,
                     block_train, init_block, layout)
from .config import BlockKind, ModelConfig
from .layers import embed, init_embed, init_rmsnorm, rmsnorm, unembed

Group = tuple  # ("scan", kind, count) | ("rep", ((kind, count), ...), n_rep)


def grouped_layout(cfg: ModelConfig) -> list[Group]:
    segs = layout(cfg)
    if cfg.family == "ssm" and cfg.slstm_every:
        k = cfg.slstm_every
        n_rep = cfg.n_layers // k
        groups: list[Group] = [("rep",
                               ((BlockKind.MLSTM, k - 1),
                                (BlockKind.SLSTM, 1)), n_rep)]
        tail = cfg.n_layers - n_rep * k
        if tail:
            groups.append(("scan", BlockKind.MLSTM, tail))
        return groups
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        n_rep = cfg.n_layers // k
        groups = [("rep", ((BlockKind.MAMBA2, k),
                           (BlockKind.SHARED_ATTN, 1)), n_rep)]
        tail = cfg.n_layers - n_rep * k
        if tail:
            groups.append(("scan", BlockKind.MAMBA2, tail))
        return groups
    return [("scan", k, c) for k, c in segs]


def _stack_init(rng, cfg, kind: BlockKind, shape: tuple[int, ...]):
    """Init a (prod(shape),)-stacked block param tree with leading dims."""
    n = 1
    for s in shape:
        n *= s
    rngs = jax.random.split(rng, n)
    stacked = jax.vmap(lambda r: init_block(r, cfg, kind))(rngs)
    if len(shape) > 1:
        stacked = jax.tree_util.tree_map(
            lambda x: x.reshape(shape + x.shape[1:]), stacked)
    return stacked


def init_lm(rng, cfg: ModelConfig) -> dict[str, Any]:
    ks = jax.random.split(rng, 8)
    params: dict[str, Any] = {
        "embed": init_embed(ks[0], cfg),
        "final_norm": init_rmsnorm(cfg.d_model, None),
        "groups": [],
    }
    for i, g in enumerate(grouped_layout(cfg)):
        kg = jax.random.fold_in(ks[1], i)
        if g[0] == "scan":
            _, kind, count = g
            params["groups"].append(_stack_init(kg, cfg, kind, (count,)))
        else:
            _, inner, n_rep = g
            gp = {}
            for j, (kind, count) in enumerate(inner):
                if kind == BlockKind.SHARED_ATTN:
                    continue  # single shared set at top level
                gp[f"seg{j}"] = _stack_init(jax.random.fold_in(kg, j), cfg,
                                            kind, (n_rep, count))
            params["groups"].append(gp)
    if cfg.shared_attn_every:
        params["shared_attn"] = init_block(ks[2], cfg,
                                           BlockKind.SHARED_ATTN)
    return params


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------


REMAT_POLICIES = ("full", "dots", "block_outs")

_ACTIVE_REMAT_POLICY = ["full"]


def set_remat_policy(name: str) -> None:
    assert name in REMAT_POLICIES, name
    _ACTIVE_REMAT_POLICY[0] = name


def _checkpoint(fn):
    name = _ACTIVE_REMAT_POLICY[0]
    if name == "dots":
        # save every matmul output: no recompute flops/collectives but
        # O(all intermediates) memory — measured infeasible at 4k seq
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_saveable)
    if name == "block_outs":
        # save ONLY the post-all-reduce block outputs (see blocks._name):
        # one (b, s, d) tensor per block — the recompute pass re-derives
        # everything else locally, re-issuing NO tensor-parallel collectives
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names(
                "block_out"))
    return jax.checkpoint(fn, prevent_cse=False)


def _scan_train(stack_params, x, cfg, kind, remat: bool):
    def body(carry, p):
        h, aux = carry
        h2, a = block_train(p, h, cfg, kind)
        return (h2, aux + a), None
    if remat:
        body = _checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               stack_params)
    return x, aux


def lm_forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
               remat: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (b, s) -> (logits (b, s, v) f32, aux loss)."""
    x = embed(params["embed"], tokens)
    aux = jnp.zeros((), jnp.float32)
    for g, gp in zip(grouped_layout(cfg), params["groups"]):
        if g[0] == "scan":
            _, kind, count = g
            x, a = _scan_train(gp, x, cfg, kind, remat)
            aux = aux + a
        else:
            _, inner, n_rep = g
            shared = params.get("shared_attn")

            def rep_body(carry, rep_p):
                h, acc = carry
                for j, (kind, count) in enumerate(inner):
                    if kind == BlockKind.SHARED_ATTN:
                        fn = jax.checkpoint(
                            functools.partial(block_train, cfg=cfg,
                                              kind=kind),
                            prevent_cse=False) if remat else \
                            functools.partial(block_train, cfg=cfg,
                                              kind=kind)
                        h, a = fn(shared, h)
                        acc = acc + a
                    else:
                        h, a = _scan_train(rep_p[f"seg{j}"], h, cfg, kind,
                                           remat)
                        acc = acc + a
                return (h, acc), None

            (x, aux), _ = jax.lax.scan(rep_body, (x, aux), gp)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), aux


def lm_loss(params, tokens: jnp.ndarray, cfg: ModelConfig,
            aux_weight: float = 0.01) -> tuple[jnp.ndarray, dict]:
    """Next-token cross entropy over tokens (b, s)."""
    logits, aux = lm_forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux,
                   "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, batch: int, max_seq: int):
    caches = []
    for g in grouped_layout(cfg):
        if g[0] == "scan":
            _, kind, count = g
            one = block_init_cache(cfg, kind, batch, max_seq)
            caches.append(jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (count,) + x.shape), one))
        else:
            _, inner, n_rep = g
            gc = {}
            for j, (kind, count) in enumerate(inner):
                one = block_init_cache(cfg, kind, batch, max_seq)
                gc[f"seg{j}"] = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (n_rep, count) + x.shape),
                    one)
            caches.append(gc)
    return caches


def lm_prefill(params, tokens: jnp.ndarray, cfg: ModelConfig, max_seq: int):
    """Prefill a prompt; returns (last-token logits, caches)."""
    x = embed(params["embed"], tokens)
    caches = []
    for g, gp in zip(grouped_layout(cfg), params["groups"]):
        if g[0] == "scan":
            _, kind, count = g

            def body(h, p):
                h2, c = block_prefill(p, h, cfg, kind, max_seq)
                return h2, c
            x, cache = jax.lax.scan(body, x, gp)
            caches.append(cache)
        else:
            _, inner, n_rep = g
            shared = params.get("shared_attn")

            def rep_body(h, rep_p):
                cs = {}
                for j, (kind, count) in enumerate(inner):
                    if kind == BlockKind.SHARED_ATTN:
                        h, c = block_prefill(shared, h, cfg, kind, max_seq)
                        cs[f"seg{j}"] = jax.tree_util.tree_map(
                            lambda y: y[None], c)
                    else:
                        def inner_body(hh, p):
                            hh2, c2 = block_prefill(p, hh, cfg, kind,
                                                    max_seq)
                            return hh2, c2
                        h, c = jax.lax.scan(inner_body, h, rep_p[f"seg{j}"])
                        cs[f"seg{j}"] = c
                return h, cs
            x, cache = jax.lax.scan(rep_body, x, gp)
            caches.append(cache)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:])
    return logits, caches


def lm_decode(params, token: jnp.ndarray, caches, cache_len: jnp.ndarray,
              cfg: ModelConfig):
    """One decode step.  token (b, 1) ids; cache_len (b,) valid lengths.
    Returns (logits (b, 1, v), new caches)."""
    x = embed(params["embed"], token)
    new_caches = []
    for g, gp, cache in zip(grouped_layout(cfg), params["groups"], caches):
        if g[0] == "scan":
            _, kind, count = g

            def body(h, pc):
                p, c = pc
                h2, c2 = block_decode(p, h, cfg, kind, c, cache_len)
                return h2, c2
            x, c2 = jax.lax.scan(body, x, (gp, cache))
            new_caches.append(c2)
        else:
            _, inner, n_rep = g
            shared = params.get("shared_attn")

            def rep_body(h, pc):
                rep_p, rep_c = pc
                out_c = {}
                for j, (kind, count) in enumerate(inner):
                    cj = rep_c[f"seg{j}"]
                    if kind == BlockKind.SHARED_ATTN:
                        c1 = jax.tree_util.tree_map(lambda y: y[0], cj)
                        h, c2 = block_decode(shared, h, cfg, kind, c1,
                                             cache_len)
                        out_c[f"seg{j}"] = jax.tree_util.tree_map(
                            lambda y: y[None], c2)
                    else:
                        def inner_body(hh, pc2):
                            p, c = pc2
                            hh2, c2 = block_decode(p, hh, cfg, kind, c,
                                                   cache_len)
                            return hh2, c2
                        h, c2 = jax.lax.scan(inner_body, h,
                                             (rep_p[f"seg{j}"], cj))
                        out_c[f"seg{j}"] = c2
                return h, out_c
            x, c2 = jax.lax.scan(rep_body, x, (gp, cache))
            new_caches.append(c2)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return unembed(params["embed"], x), new_caches
