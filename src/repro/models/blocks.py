"""Block assembly + layer layout: one residual block per layer kind, and the
segment machinery that stacks homogeneous layer runs for jax.lax.scan (keeps
the HLO compact — essential for 81-layer models on a 512-way mesh).

Layouts:
  dense/moe/vlm/audio : [ATTN x n_layers]                        (one scan)
  ssm (xLSTM)         : [(MLSTM x (k-1), SLSTM) x n_rep]         (outer scan)
  hybrid (zamba2)     : [(MAMBA2 x k, SHARED_ATTN) x n_rep, MAMBA2 x tail]
                        — SHARED_ATTN reuses ONE param set at every
                        application (the zamba2 weight-sharing trick), but
                        each application carries its own KV cache.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import BlockKind, ModelConfig
from .layers import (Params, attention, attention_decode, attention_prefill,
                     init_attention, init_mlp, init_rmsnorm, mlp, rmsnorm)
from .mamba2 import (init_mamba2, mamba2_block, mamba2_decode,
                     mamba2_init_state)
from .moe import init_moe, moe_mlp
from .xlstm import (init_mlstm, init_slstm, mlstm_block, mlstm_decode,
                    mlstm_init_state, slstm_block, slstm_decode,
                    slstm_init_state)


# ---------------------------------------------------------------------------
# single blocks
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: BlockKind) -> Params:
    ks = jax.random.split(rng, 4)
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        p = {"ln1": init_rmsnorm(cfg.d_model, None),
             "attn": init_attention(ks[0], cfg),
             "ln2": init_rmsnorm(cfg.d_model, None)}
        if cfg.moe and kind == BlockKind.ATTN:
            p["moe"] = init_moe(ks[1], cfg)
        else:
            p["mlp"] = init_mlp(ks[1], cfg)
        return p
    if kind == BlockKind.MAMBA2:
        return {"ln1": init_rmsnorm(cfg.d_model, None),
                "mamba": init_mamba2(ks[0], cfg)}
    if kind == BlockKind.MLSTM:
        return {"ln1": init_rmsnorm(cfg.d_model, None),
                "mlstm": init_mlstm(ks[0], cfg)}
    if kind == BlockKind.SLSTM:
        return {"ln1": init_rmsnorm(cfg.d_model, None),
                "slstm": init_slstm(ks[0], cfg)}
    raise ValueError(kind)


def _name(x, tag: str):
    """checkpoint_name hook: the 'block_out' activations are what the
    selective remat policy saves — they sit just AFTER each block's tensor-
    parallel all-reduce, so the backward recompute pass never re-issues
    those collectives (§Perf hillclimb #1, iteration 3)."""
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(x, tag)


def block_train(p: Params, x, cfg: ModelConfig, kind: BlockKind):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        x = x + _name(attention(p["attn"],
                                rmsnorm(p["ln1"], x, cfg.norm_eps), cfg),
                      "block_out")
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out, aux = moe_mlp(p["moe"], h, cfg)
        else:
            out = mlp(p["mlp"], h)
        return x + _name(out, "block_out"), aux
    if kind == BlockKind.MAMBA2:
        return x + _name(
            mamba2_block(p["mamba"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                         cfg), "block_out"), aux
    if kind == BlockKind.MLSTM:
        return x + mlstm_block(p["mlstm"],
                               rmsnorm(p["ln1"], x, cfg.norm_eps), cfg), aux
    if kind == BlockKind.SLSTM:
        return x + slstm_block(p["slstm"],
                               rmsnorm(p["ln1"], x, cfg.norm_eps), cfg), aux
    raise ValueError(kind)


def block_init_cache(cfg: ModelConfig, kind: BlockKind, batch: int,
                     max_seq: int):
    dt = jnp.dtype(cfg.dtype)
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        shape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            sshape = (batch, max_seq, cfg.n_kv_heads)
            return (jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                    jnp.zeros(sshape, jnp.float16),
                    jnp.zeros(sshape, jnp.float16))
        return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))
    if kind == BlockKind.MAMBA2:
        return mamba2_init_state(cfg, batch)
    if kind == BlockKind.MLSTM:
        return mlstm_init_state(cfg, batch)
    if kind == BlockKind.SLSTM:
        return slstm_init_state(cfg, batch)
    raise ValueError(kind)


def block_prefill(p: Params, x, cfg: ModelConfig, kind: BlockKind,
                  max_seq: int):
    """Returns (x, cache) — cache padded to max_seq for attention kinds."""
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        out, (k, v) = attention_prefill(p["attn"], h, cfg)
        x = x + out
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out2, _ = moe_mlp(p["moe"], h2, cfg)
        else:
            out2 = mlp(p["mlp"], h2)
        b, s = x.shape[0], k.shape[1]
        pad = max_seq - s
        if cfg.kv_cache_dtype == "int8":
            from .layers import _quantize_kv
            kq, ks = _quantize_kv(k)
            vq, vs = _quantize_kv(v)
            pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
            pad3 = ((0, 0), (0, pad), (0, 0))
            return x + out2, (jnp.pad(kq, pad4), jnp.pad(vq, pad4),
                              jnp.pad(ks, pad3), jnp.pad(vs, pad3))
        kc = jnp.pad(k.astype(jnp.dtype(cfg.dtype)),
                     ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v.astype(jnp.dtype(cfg.dtype)),
                     ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x + out2, (kc, vc)
    # Recurrent kinds: output from the parallel form; the decode-entry state
    # is rebuilt with a sequential replay scan.  (A production TPU prefill
    # would carry the chunk-final state out of _ssd_chunked instead; the
    # replay keeps this reference implementation simple and exact.)
    if kind == BlockKind.MAMBA2:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y = mamba2_block(p["mamba"], h, cfg)
        state, _ = jax.lax.scan(
            lambda st, xt: (mamba2_decode(p["mamba"], xt[:, None], cfg,
                                          st)[1], None),
            mamba2_init_state(cfg, x.shape[0]), h.swapaxes(0, 1))
        return x + y, state
    if kind == BlockKind.MLSTM:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y = mlstm_block(p["mlstm"], h, cfg)
        state, _ = jax.lax.scan(
            lambda st, xt: (mlstm_decode(p["mlstm"], xt[:, None], cfg,
                                         st)[1], None),
            mlstm_init_state(cfg, x.shape[0]), h.swapaxes(0, 1))
        return x + y, state
    if kind == BlockKind.SLSTM:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y = slstm_block(p["slstm"], h, cfg)
        state, _ = jax.lax.scan(
            lambda st, xt: (slstm_decode(p["slstm"], xt[:, None], cfg,
                                         st)[1], None),
            slstm_init_state(cfg, x.shape[0]), h.swapaxes(0, 1))
        return x + y, state
    raise ValueError(kind)


def block_decode(p: Params, x, cfg: ModelConfig, kind: BlockKind, cache,
                 cache_len):
    """One token; returns (x, cache)."""
    if kind in (BlockKind.ATTN, BlockKind.SHARED_ATTN):
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        out, cache = attention_decode(p["attn"], h, cfg, cache, cache_len)
        x = x + out
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "moe" in p:
            out2, _ = moe_mlp(p["moe"], h2, cfg)
        else:
            out2 = mlp(p["mlp"], h2)
        return x + out2, cache
    if kind == BlockKind.MAMBA2:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = mamba2_decode(p["mamba"], h, cfg, cache)
        return x + y, cache
    if kind == BlockKind.MLSTM:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = mlstm_decode(p["mlstm"], h, cfg, cache)
        return x + y, cache
    if kind == BlockKind.SLSTM:
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        y, cache = slstm_decode(p["slstm"], h, cfg, cache)
        return x + y, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# layer layout
# ---------------------------------------------------------------------------


def layout(cfg: ModelConfig) -> list[tuple[BlockKind, int]]:
    """Flat (kind, count) segment list describing the layer stack.

    Segments with count > 1 are scan-stacked; the hybrid/xLSTM repeating
    units are expressed by repeating segments (the apply code groups equal
    consecutive patterns into an outer scan where possible)."""
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return [(BlockKind.ATTN, cfg.n_layers)]
    if cfg.family == "ssm":
        k = cfg.slstm_every
        if not k:
            return [(BlockKind.MLSTM, cfg.n_layers)]
        segs: list[tuple[BlockKind, int]] = []
        n_rep = cfg.n_layers // k
        for _ in range(n_rep):
            segs.append((BlockKind.MLSTM, k - 1))
            segs.append((BlockKind.SLSTM, 1))
        tail = cfg.n_layers - n_rep * k
        if tail:
            segs.append((BlockKind.MLSTM, tail))
        return segs
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        segs = []
        n_rep = cfg.n_layers // k
        for _ in range(n_rep):
            segs.append((BlockKind.MAMBA2, k))
            segs.append((BlockKind.SHARED_ATTN, 1))
        tail = cfg.n_layers - n_rep * k
        if tail:
            segs.append((BlockKind.MAMBA2, tail))
        return segs
    raise ValueError(cfg.family)
