from .config import BlockKind, ModelConfig
from .lm import (grouped_layout, init_caches, init_lm, lm_decode,
                 lm_forward, lm_loss, lm_prefill)
from .encdec import (encdec_decode, encdec_forward, encdec_init_caches,
                     encdec_loss, encode, init_encdec)

__all__ = ["BlockKind", "ModelConfig", "grouped_layout", "init_caches",
           "init_lm", "lm_decode", "lm_forward", "lm_loss", "lm_prefill",
           "encdec_decode", "encdec_forward", "encdec_init_caches",
           "encdec_loss", "encode", "init_encdec"]
