"""Mamba2 (SSD) block: chunked state-space duality for training/prefill and
O(1)-state recurrence for decode.  Single B/C group, scalar-per-head A —
the Mamba2 paper's default ([arXiv:2405.21060])."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from .layers import Params, _dtype, _init

CONV_K = 4


def dims(cfg) -> tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    return d_in, n_heads, cfg.ssm_state


def init_mamba2(rng, cfg) -> Params:
    d = cfg.d_model
    d_in, nh, n = dims(cfg)
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 6)
    conv_ch = d_in + 2 * n
    return {
        # z (gate) + x + B + C + dt heads
        "in_proj": _init(ks[0], (d, 2 * d_in + 2 * n + nh), d ** -0.5, dt),
        "conv_w": _init(ks[1], (CONV_K, conv_ch), 0.5, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_proj": _init(ks[2], (d_in, d), d_in ** -0.5, dt),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
    }


def _split_proj(p, x, cfg):
    d_in, nh, n = dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * n]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xbc, dt_raw


def _causal_conv(p, xbc, conv_state=None):
    """Depthwise causal conv (k=4).  xbc: (b, s, ch).  If conv_state (b,
    k-1, ch) given (decode), uses and returns the rolled state."""
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xbc], axis=1)  # (b, k, ch)
        out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        return jax.nn.silu(out)[:, None], window[:, 1:]
    b, s, ch = xbc.shape
    pad = jnp.zeros((b, CONV_K - 1, ch), xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(CONV_K))
    return jax.nn.silu(out + p["conv_b"]), None


def _ssd_chunked(x, dtv, B, C, a_log, chunk: int):
    """SSD scan. x: (b, s, nh, P); dtv: (b, s, nh); B, C: (b, s, N).
    Returns y (b, s, nh, P)."""
    b, s, nh, P = x.shape
    n = B.shape[-1]
    Q = min(chunk, s)
    nc = s // Q
    A = -jnp.exp(a_log)                                  # (nh,) negative
    xc = x.reshape(b, nc, Q, nh, P).astype(jnp.float32)
    dtc = dtv.reshape(b, nc, Q, nh)
    Bc = B.reshape(b, nc, Q, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, n).astype(jnp.float32)
    loga = dtc * A                                        # (b, nc, Q, nh)
    cum = jnp.cumsum(loga, axis=2)

    # intra-chunk (quadratic within chunks).  Mask BEFORE exp: exp(li-lj)
    # overflows for masked upper-triangular entries (li > lj there) and
    # where(mask, inf, 0) still propagates NaN through the backward pass.
    li = cum[:, :, :, None, :]                            # i index
    lj = cum[:, :, None, :, :]                            # j index
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    ldiff = jnp.where(mask[None, None, :, :, None], li - lj, -1e30)
    L = jnp.exp(ldiff)                                    # (b,nc,Q,Q,nh)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (b,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp",
                         cb, L, dtc, xc)

    # chunk state contributions
    tail = cum[:, :, -1:, :] - cum                        # prod_{k>j} a_k
    states = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn",
                        jnp.exp(tail), dtc, xc, Bc)       # (b,nc,nh,P,N)
    decay_chunk = jnp.exp(cum[:, :, -1, :])               # (b,nc,nh)

    def scan_fn(h, inp):
        st, dc = inp
        h_new = h * dc[:, :, None, None] + st
        return h_new, h

    h0 = jnp.zeros((b, nh, P, n), jnp.float32)
    _, h_in = jax.lax.scan(scan_fn, h0,
                           (states.swapaxes(0, 1), decay_chunk.swapaxes(0, 1)))
    h_in = h_in.swapaxes(0, 1)                            # (b,nc,nh,P,N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc, jnp.exp(cum), h_in)
    y = (y_intra + y_inter).reshape(b, s, nh, P)
    return y


def mamba2_block(p: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Training/prefill path.  x: (b, s, d) -> (b, s, d)."""
    b, s0, d = x.shape
    pad = (-s0) % min(cfg.ssm_chunk, max(s0, 1))
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = x.shape[1]
    d_in, nh, n = dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    xbc, _ = _causal_conv(p, xbc)
    xs = xbc[..., :d_in].reshape(b, s, nh, cfg.ssm_head_dim)
    B = xbc[..., d_in:d_in + n]
    C = xbc[..., d_in + n:]
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y = _ssd_chunked(xs, dtv, B, C, p["a_log"], cfg.ssm_chunk)
    if pad:
        y, xs, z, x = y[:, :s0], xs[:, :s0], z[:, :s0], x[:, :s0]
        s = s0
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_in)
    # gated RMS-norm then out projection
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return shard(y @ p["out_proj"], "batch", "seq", None)


def mamba2_init_state(cfg, batch: int):
    d_in, nh, n = dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, conv_ch), jnp.dtype(cfg.dtype)),
    }


def mamba2_decode(p: Params, x: jnp.ndarray, cfg, state):
    """One-token recurrence.  x: (b, 1, d); state: {'h', 'conv'}."""
    b = x.shape[0]
    d_in, nh, n = dims(cfg)
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    conv_out, conv_state = _causal_conv(p, xbc, state["conv"])
    xs = conv_out[:, 0, :d_in].reshape(b, nh, cfg.ssm_head_dim)
    B = conv_out[:, 0, d_in:d_in + n].astype(jnp.float32)
    C = conv_out[:, 0, d_in + n:].astype(jnp.float32)
    dtv = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(dtv * -jnp.exp(p["a_log"]))               # (b, nh)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xs.astype(jnp.float32), B)
    y = jnp.einsum("bn,bhpn->bhp", C, h)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-5) * p["norm_scale"]).astype(x.dtype)
    return y @ p["out_proj"], {"h": h, "conv": conv_state}
