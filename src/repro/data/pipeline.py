"""Synthetic deterministic data pipeline with host-side prefetch.

Step-indexed and shard-aware: batch(step, shard, n_shards) is a pure
function, so exact resume after restart/rollback needs no iterator state,
and elastic re-sharding (different n_shards) re-partitions the same global
stream.  A background thread keeps a bounded prefetch queue full — the
host-side analogue of MAGE's lookahead.
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 1234
    frames_dim: int = 0     # >0: also emit encoder frame embeddings (audio)


def batch_for_step(cfg: DataConfig, step: int, shard: int = 0,
                   n_shards: int = 1) -> dict[str, np.ndarray]:
    """Deterministic batch: token ids drawn per (step, global row index)."""
    per = cfg.global_batch // n_shards
    rows = np.arange(shard * per, (shard + 1) * per, dtype=np.uint64)
    out: dict[str, np.ndarray] = {}
    rng = np.random.Philox(key=cfg.seed + step)
    gen = np.random.Generator(rng)
    all_tokens = gen.integers(0, cfg.vocab_size,
                              (cfg.global_batch, cfg.seq_len),
                              dtype=np.int32)
    out["tokens"] = all_tokens[rows.astype(np.int64)]
    if cfg.frames_dim:
        frames = gen.normal(0, 1, (cfg.global_batch, cfg.seq_len,
                                   cfg.frames_dim)).astype(np.float32)
        out["frames"] = frames[rows.astype(np.int64)]
    return out


class Prefetcher:
    """Bounded background prefetch of step batches."""

    def __init__(self, cfg: DataConfig, start_step: int, shard: int = 0,
                 n_shards: int = 1, depth: int = 2):
        self.cfg = cfg
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self.shard, self.n_shards = shard, n_shards
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            b = batch_for_step(self.cfg, s, self.shard, self.n_shards)
            while not self._stop.is_set():
                try:
                    self.q.put((s, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self) -> tuple[int, dict[str, np.ndarray]]:
        return self.q.get()

    def close(self):
        self._stop.set()
