"""Gradient compression for data-parallel all-reduce: int8 quantization with
stochastic rounding and error feedback (1-bit-Adam-family trick, adapted to
jax collectives).  Used inside shard_map'd all-reduce when enabled; the
error-feedback residual is carried in the optimizer state.

At 512+ chips the DP all-reduce of a 7B-param bf16 gradient is ~14 GB of
traffic per step per direction; int8 halves it and the residual keeps the
update unbiased in expectation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, key: jax.Array
                  ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor scale, stochastic rounding.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name, key: jax.Array,
                    residual: jnp.ndarray | None = None
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """psum with int8 payload + error feedback.

    Returns (summed f32, new residual).  Must run inside shard_map with
    ``axis_name`` bound.  The scale is max-reduced first so every shard
    quantizes on the same grid (otherwise the sum of per-shard scales would
    dequantize incorrectly)."""
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12),
                         axis_name) / 127.0
    y = xf / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127)
    new_residual = xf - q * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_residual
