"""GPipe-style pipeline parallelism over the pod axis (differentiable).

Implements the collective-pipeline pattern: shard_map over the 'pod' axis,
each pod holding a contiguous stage of layers; microbatch activations flow
stage-to-stage with collective_permute inside a python loop of
n_micro + n_stages - 1 ticks.  Because ppermute is differentiable, jax.grad
through the whole step yields the reverse pipeline automatically — no
hand-written backward schedule.

Applies to single-scan layouts (dense/MoE/VLM); embed/unembed params are
replicated across stages.  Inter-pod traffic: one (micro_b, seq, d_model)
activation per tick per boundary — the right trade when pod-to-pod ICI is
the scarce link (vs a full-gradient DP all-reduce).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..models.blocks import block_train
from ..models.config import BlockKind, ModelConfig
from ..models.layers import embed, rmsnorm, unembed


def split_stage_params(params, n_stages: int):
    """Reshape the (L, ...) scanned stack into (n_stages, L/S, ...)."""
    stack = params["groups"][0]
    resh = jax.tree_util.tree_map(
        lambda x: x.reshape((n_stages, x.shape[0] // n_stages) + x.shape[1:]),
        stack)
    out = dict(params)
    out["groups"] = [resh]
    return out


def stage_param_specs(params, n_stages: int, rules):
    """PartitionSpecs: stage stack sharded over 'pod' on dim 0; embed/norm
    replicated."""
    from .sharding import params_pspecs
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
    specs = params_pspecs(shapes, rules)

    def add_pod(leaf, spec):
        inner = (list(spec) + [None] * leaf.ndim)[:leaf.ndim - 1]
        return P("pod", *inner)
    specs["groups"] = [jax.tree_util.tree_map(
        add_pod, params["groups"][0], specs["groups"][0])]
    return specs


def pipeline_loss(params, tokens, cfg: ModelConfig, mesh, n_micro: int,
                  rules) -> jnp.ndarray:
    """Pipelined forward+loss; differentiable.  tokens: (B, S) sharded over
    'data' on batch.  Stage stacks sharded over 'pod'."""
    n_stages = mesh.shape["pod"]
    specs = stage_param_specs(params, n_stages, rules)
    data_spec = P(("data",), None)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(specs, data_spec),
        out_specs=P(),
        check_rep=False)
    def run(p, toks):
        stage = jax.lax.axis_index("pod")
        stack = jax.tree_util.tree_map(lambda x: x[0], p["groups"][0])
        b, s = toks.shape
        mb = b // n_micro
        micro = toks.reshape(n_micro, mb, s)

        def apply_stage(x):
            def body(h, blk):
                h2, _ = block_train(blk, h, cfg, BlockKind.ATTN)
                return h2, None
            x, _ = jax.lax.scan(body, x, stack)
            return x

        buf = jnp.zeros((mb, s - 1, cfg.d_model),
                        jnp.dtype(cfg.dtype))
        loss_acc = jnp.zeros((), jnp.float32)
        n_ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]
        for t in range(n_ticks):
            feed_idx = min(t, n_micro - 1)
            inject = embed(p["embed"], micro[feed_idx][:, :-1])
            x = jnp.where(stage == 0,
                          inject.astype(buf.dtype), buf)
            x = apply_stage(x)
            # last stage: finalize loss for microbatch t-(n_stages-1)
            out_idx = t - (n_stages - 1)
            if out_idx >= 0:
                tgt = micro[out_idx][:, 1:]

                def _loss(h):
                    h = rmsnorm(p["final_norm"], h, cfg.norm_eps)
                    logits = unembed(p["embed"], h)
                    logp = jax.nn.log_softmax(logits, axis=-1)
                    nll = -jnp.take_along_axis(logp, tgt[..., None],
                                               axis=-1)[..., 0]
                    return jnp.mean(nll)

                loss_acc = loss_acc + jax.lax.cond(
                    stage == n_stages - 1, _loss,
                    lambda h: jnp.zeros((), jnp.float32), x)
            buf = jax.lax.ppermute(x, "pod", perm)
        total = jax.lax.psum(loss_acc / n_micro, "pod")
        total = jax.lax.pmean(total, "data")
        return total

    return run(params, tokens)
