"""Logical-axis sharding rules (DP/TP/EP/SP) for the whole framework.

Activations are constrained inside model code via ``shard(x, *logical)``;
parameters get PartitionSpecs from name-based rules over the pytree path.
Changing ``AxisRules`` is the perf lever the §Perf hillclimbs turn (e.g.
flipping sequence sharding on for long prefill).

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The pod axis joins data-parallelism by default (pipeline parallelism over
pods is available in train_step as an alternative strategy).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class AxisRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""
    rules: dict[str, Any]
    grad_compression: str | None = None   # None | 'int8' (accounting flag)

    def axis(self, logical: str):
        return self.rules.get(logical)

    def spec(self, *logical: str | None) -> P:
        return P(*(self.axis(l) if l else None for l in logical))


def default_rules(mesh: jax.sharding.Mesh,
                  seq_sharding: bool = False) -> AxisRules:
    names = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in names)
    data = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes
                                                 else None)
    model = "model" if "model" in names else None
    return AxisRules(rules={
        "batch": data,
        "seq": model if seq_sharding else None,  # SP: shard activations' seq
        "heads": model,
        "kv_heads": model,
        "ff": model,
        "vocab": model,
        "experts": model,
        "dmodel": None,
        "kv_seq": None,
        "state": None,
    })


def rules_for(cfg, mesh: jax.sharding.Mesh,
              seq_sharding: bool = False,
              dp_over_model: bool = False) -> AxisRules:
    """Per-config rules: a logical axis maps to the model mesh axis only if
    the corresponding dimension is divisible by the axis size (GQA models
    with few KV heads replicate KV; odd head counts fall back to ff/vocab
    tensor parallelism).

    ``dp_over_model``: fold the model axis into data parallelism (weights
    replicated, zero TP collectives) — the right strategy for models small
    enough to replicate, where TP activation all-reduces dominate the step
    (§Perf hillclimb #1)."""
    rules = default_rules(mesh, seq_sharding=seq_sharding)
    msize = mesh.shape.get("model", 1)
    if dp_over_model:
        names = mesh.axis_names
        data = tuple(a for a in ("pod", "data", "model") if a in names)
        for k in rules.rules:
            rules.rules[k] = None
        rules.rules["batch"] = data
        rules.rules["scores_q"] = None
        rules.rules["kv_seq"] = None
        return rules

    def ok(dim: int) -> bool:
        return dim % msize == 0 and dim >= msize

    if not ok(cfg.n_heads):
        rules.rules["heads"] = None
    if not ok(cfg.n_kv_heads):
        rules.rules["kv_heads"] = None
    if not ok(cfg.d_ff if cfg.d_ff else cfg.ssm_expand * cfg.d_model):
        rules.rules["ff"] = None
    if not ok(cfg.vocab_size):
        rules.rules["vocab"] = None
    if cfg.moe and not ok(cfg.n_experts):
        rules.rules["experts"] = None
    if seq_sharding:
        # pure sequence parallelism: the model axis shards the sequence dim
        # of every activation; weight axes must then be replicated (a tensor
        # can't map one mesh axis twice)
        for k in ("heads", "kv_heads", "ff", "vocab", "experts"):
            rules.rules[k] = None
        rules.rules["seq"] = "model"
    # attention-score sharding: if KV heads cannot shard (GQA with few KV
    # heads), bound the (b, kv, group, sq, skv) scores tensor by sharding
    # the query-sequence dim instead
    rules.rules["scores_q"] = ("model" if rules.rules.get("kv_heads") is None
                               and msize > 1 else None)
    # KV-cache sequence sharding: with unshardable KV heads the decode cache
    # would otherwise be replicated across the model axis — shard its seq
    # dim instead (the contraction over seq then reduces with a psum)
    rules.rules["kv_seq"] = ("model" if rules.rules.get("kv_heads") is None
                             and msize > 1 else None)
    return rules


_CURRENT: list[AxisRules] = []


class use_rules:
    def __init__(self, rules: AxisRules):
        self.rules = rules

    def __enter__(self):
        _CURRENT.append(self.rules)
        return self.rules

    def __exit__(self, *a):
        _CURRENT.pop()


def shard(x, *logical: str | None):
    """with_sharding_constraint under the active rules (no-op outside)."""
    if not _CURRENT:
        return x
    spec = _CURRENT[-1].spec(*logical)
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# parameter shardings from pytree path names
# ---------------------------------------------------------------------------

# (regex over the param path, logical axes per dim — trailing dims matched
# right-aligned; stacked-layer leading dims are left unsharded)
PARAM_RULES: list[tuple[str, tuple[str | None, ...]]] = [
    (r"embed", ("vocab", None)),
    (r"lm_head", (None, "vocab")),
    (r"(wq|wkv_a|q_proj)$", (None, "heads")),
    (r"(wk|wv|k_proj|v_proj)$", (None, "kv_heads")),
    (r"(wo|o_proj)$", ("heads", None)),
    (r"(q_bias)$", ("heads",)),
    (r"(k_bias|v_bias)$", ("kv_heads",)),
    (r"(w_gate|w_up|gate_proj|up_proj)$", (None, "ff")),
    (r"(w_down|down_proj)$", ("ff", None)),
    (r"experts_.*(gate|up)$", ("experts", None, None)),
    (r"experts_.*down$", ("experts", None, None)),
    (r"router$", (None, "experts")),
    (r"(in_proj|xbc_proj)$", (None, "ff")),
    (r"(ssm_out|out_proj)$", ("ff", None)),
    (r"(mq|mk|mv)$", (None, "heads")),
    (r"m_out$", ("heads", None)),
]


def param_spec_for_path(path: str, ndim: int, rules: AxisRules) -> P:
    for pat, logical in PARAM_RULES:
        if re.search(pat, path):
            axes = [rules.axis(l) if l else None for l in logical]
            if len(axes) < ndim:           # stacked layers etc: left-pad
                axes = [None] * (ndim - len(axes)) + axes
            elif len(axes) > ndim:
                axes = axes[-ndim:]
            return P(*axes)
    return P()  # replicate


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def params_pspecs(params_shape: Any, rules: AxisRules) -> Any:
    """Pytree of PartitionSpec for a params pytree (of ShapeDtypeStruct)."""
    def fn(path, leaf):
        return param_spec_for_path(_path_str(path), len(leaf.shape), rules)
    return jax.tree_util.tree_map_with_path(fn, params_shape)


def params_shardings(params_shape: Any, mesh: jax.sharding.Mesh,
                     rules: AxisRules) -> Any:
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        params_pspecs(params_shape, rules),
        is_leaf=lambda x: isinstance(x, P))


def zero_pspecs(params_shape: Any, rules: AxisRules,
                mesh: jax.sharding.Mesh) -> Any:
    """ZeRO-style specs for optimizer state / gradient accumulators: on top
    of the parameter sharding, shard the first still-unsharded divisible dim
    over the data axes.  Weights stay DP-replicated (needed for fwd); the
    8-16 bytes/param of moments+f32 grads — the bulk at MoE scale — shard
    dp-ways, and XLA inserts the ZeRO all-gather on the updated params."""
    base = params_pspecs(params_shape, rules)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not data_axes:
        return base
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    data = data_axes if len(data_axes) > 1 else data_axes[0]

    def fn(leaf, spec):
        dims = list(spec) + [None] * (leaf.ndim - len(spec))
        for i in range(leaf.ndim):
            if dims[i] is None and leaf.shape[i] % dp == 0 \
                    and leaf.shape[i] >= dp:
                dims[i] = data
                return P(*dims)
        return P(*dims)

    return jax.tree_util.tree_map(fn, params_shape, base,
                                  is_leaf=lambda x: isinstance(
                                      x, jax.ShapeDtypeStruct))
