from .sharding import (AxisRules, default_rules, param_spec_for_path,
                       params_pspecs, params_shardings, rules_for, shard,
                       use_rules)

__all__ = ["AxisRules", "default_rules", "param_spec_for_path",
           "params_pspecs", "params_shardings", "rules_for", "shard",
           "use_rules"]
