"""Serving steps: prefill and decode, batched requests.

``serve_step`` = one decode step (one new token for every sequence in the
batch against its KV cache) — this is what decode_32k / long_500k lower.
``prefill_step`` processes the full prompt — what prefill_32k lowers.
Sampling is greedy/temperature; the batcher groups requests to the model's
batch size.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models import ModelConfig, encdec_decode, lm_decode, lm_prefill


def prefill_step(params, tokens: jnp.ndarray, cfg: ModelConfig,
                 max_seq: int):
    """Prompt processing; returns (next-token logits, caches)."""
    return lm_prefill(params, tokens, cfg, max_seq)


def serve_step(params, token: jnp.ndarray, caches, cache_len: jnp.ndarray,
               cfg: ModelConfig, temperature: float = 0.0,
               rng: jax.Array | None = None):
    """One decode step; returns (next token ids (b, 1), caches, logits)."""
    logits, caches = lm_decode(params, token, caches, cache_len, cfg)
    if temperature > 0.0 and rng is not None:
        nxt = jax.random.categorical(rng, logits[:, -1] / temperature)
        nxt = nxt[:, None]
    else:
        nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    return nxt.astype(jnp.int32), caches, logits


def serve_step_encdec(params, token, memory, caches, cache_len,
                      cfg: ModelConfig):
    logits, caches = encdec_decode(params, token, memory, caches, cache_len,
                                   cfg)
    nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    return nxt.astype(jnp.int32), caches, logits


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Any            # np.ndarray of token ids
    max_new: int = 16
    done: bool = False
    output: list = dataclasses.field(default_factory=list)


class Batcher:
    """Greedy static batcher: fills slots with pending requests; a slot
    frees when its request finishes (continuous batching lite)."""

    def __init__(self, batch_size: int):
        self.batch = batch_size
        self.pending: list[Request] = []
        self.active: list[Request | None] = [None] * batch_size

    def submit(self, req: Request):
        self.pending.append(req)

    def fill(self) -> list[tuple[int, Request]]:
        placed = []
        for i in range(self.batch):
            if self.active[i] is None and self.pending:
                self.active[i] = self.pending.pop(0)
                placed.append((i, self.active[i]))
        return placed

    def retire(self, i: int):
        self.active[i] = None

    def busy(self) -> bool:
        return any(self.active) or bool(self.pending)
