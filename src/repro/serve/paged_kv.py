"""Paged KV cache with MAGE-planned page schedules (DESIGN.md §4).

Decode's KV access pattern is oblivious: step t appends one token and scans
all previous pages.  That lets the MAGE planner (core/) precompute the page
residency/prefetch schedule for an HBM budget — identical machinery to the
SC memory programs, applied to serving:

  * pages are allocated from a free list as sequences grow;
  * with an HBM budget smaller than the full cache, the planner emits which
    pages to ISSUE-SWAP-IN from host ahead of the step that reads them
    (the trace is `for t: read pages[0..t/page], append page t/page`);
  * the attention over resident pages runs through the Pallas
    paged-attention kernel (kernels/paged_attn).

On real hardware the swap directives become host<->HBM DMAs; here the
schedule itself (a MAGE memory program) is the artifact under test.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.bytecode import Instr, Op, Program
from ..core.planner import PlanConfig, plan


@dataclasses.dataclass
class PagedKVConfig:
    page_size: int = 64           # tokens per KV page
    max_pages_per_seq: int = 512


class PagedKVCache:
    """Block-table paged KV storage for one layer group.

    k/v pages: (num_pages, page_size, kv_heads, head_dim); block tables
    (batch, max_pages)."""

    def __init__(self, cfg: PagedKVConfig, num_pages: int, batch: int,
                 kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.cfg = cfg
        shape = (num_pages, cfg.page_size, kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)
        self.block_table = np.full((batch, cfg.max_pages_per_seq), -1,
                                   dtype=np.int32)
        self.seq_lens = np.zeros((batch,), dtype=np.int32)
        self._free = list(range(num_pages - 1, -1, -1))

    def alloc_page(self, seq: int) -> int:
        page = self._free.pop()
        n = self.seq_lens[seq] // self.cfg.page_size
        self.block_table[seq, n] = page
        return page

    def append(self, seq: int, k_tok: jnp.ndarray, v_tok: jnp.ndarray):
        """Append one token's K/V for sequence ``seq``."""
        pos = int(self.seq_lens[seq])
        if pos % self.cfg.page_size == 0:
            self.alloc_page(seq)
        page = int(self.block_table[seq, pos // self.cfg.page_size])
        off = pos % self.cfg.page_size
        self.k_pages = self.k_pages.at[page, off].set(k_tok)
        self.v_pages = self.v_pages.at[page, off].set(v_tok)
        self.seq_lens[seq] = pos + 1


def decode_kv_trace(total_tokens: int, page_size: int,
                    kv_page_slots: int = 1) -> Program:
    """The oblivious KV access trace of a full decode as MAGE bytecode:
    step t writes page t//page_size and reads all pages 0..t//page_size.

    Coarsened to page granularity (one slot per page), this feeds the MAGE
    planner directly — replacement + prefetch schedules for a bounded HBM
    page budget."""
    instrs = []
    n_pages = (total_tokens + page_size - 1) // page_size
    for t in range(0, total_tokens, page_size):
        p_cur = t // page_size
        # the current page is appended to (written)...
        instrs.append(Instr(Op.COPY,
                            outs=((p_cur * kv_page_slots, kv_page_slots),),
                            ins=((p_cur * kv_page_slots, kv_page_slots),)))
        # ...and the attention streams every earlier page, one instruction
        # per page (matching the paged-attention kernel's page loop), so a
        # bounded HBM budget can pipeline the stream with prefetch.
        for p in range(p_cur):
            instrs.append(Instr(Op.COPY,
                                outs=(),
                                ins=((p * kv_page_slots, kv_page_slots),)))
    return Program(instrs=instrs, page_shift=0, protocol="kv",
                   vspace_slots=n_pages * kv_page_slots,
                   meta={"total_tokens": total_tokens,
                         "page_size": page_size})


def plan_kv_schedule(total_tokens: int, page_size: int, hbm_pages: int,
                     lookahead: int = 4, prefetch: int = 2):
    """MAGE memory program for a decode whose KV does not fit in HBM.

    Returns (memory program, plan report).  NOTE: when the budget is below
    the full working set the schedule thrashes by necessity (every step
    reads every page); the planner's output quantifies exactly how much —
    this mirrors the paper's observation that MIN cannot beat bandwidth,
    only latency."""
    prog = decode_kv_trace(total_tokens, page_size)
    cfg = PlanConfig(num_frames=hbm_pages, lookahead=lookahead,
                     prefetch_pages=prefetch)
    return plan(prog, cfg)
