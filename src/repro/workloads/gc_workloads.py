"""The five garbled-circuit workloads (§8.1.1): merge, sort, ljoin, mvmul,
binfclayer — written in the Integer DSL against the chunk library.

Problem sizes follow the paper's conventions: n records per party for
merge/sort/ljoin (128-bit records, 32-bit keys), n = matrix side for
mvmul/binfclayer.  Inputs are deterministic per (workload, n, tag).
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import Op
from ..core.workers import ProgramOptions
from ..protocols.garbled.dsl import Integer, Party
from .base import GC_PAGE_SHIFT, Workload, register
from .gc_library import (GC_CHUNK, KEY_W, RECORD_W, bitonic_merge_sorted_chunks,
                         bitonic_sort_chunks, distributed_merge_chunks,
                         input_chunks, output_chunks)

A_TAGS = 0
B_TAGS = 1 << 20
OUT_TAGS = 1 << 24


def _key_sort(rec: np.ndarray) -> np.ndarray:
    """Sort records by their 32-bit key (low bits), stably."""
    return rec[np.argsort(rec & np.uint64((1 << 32) - 1), kind="stable")]


def _records(n: int, seed: int, sort: bool) -> np.ndarray:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 1 << 31, n, dtype=np.uint64)
    payload = rng.integers(0, 1 << 31, n, dtype=np.uint64)
    rec = keys | (payload << np.uint64(32))
    return _key_sort(rec) if sort else rec


def _chunk_provider(data_by_base: dict[int, np.ndarray], chunk: int):
    def provider(tag: int) -> np.ndarray:
        for base, data in data_by_base.items():
            if base <= tag < base + (1 << 20):
                i = tag - base
                return data[i * chunk:(i + 1) * chunk]
        raise KeyError(tag)
    return provider


# ---------------------------------------------------------------------------
# merge
# ---------------------------------------------------------------------------


def _merge_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    p = opts.num_workers
    if p == 1:
        a = input_chunks(n, Party.Garbler, A_TAGS)
        b = input_chunks(n, Party.Evaluator, B_TAGS)
        out = bitonic_merge_sorted_chunks(a, b, opts)
        output_chunks(out, OUT_TAGS)
        return
    # distributed: worker w holds its block of [A asc | B desc]
    assert p % 2 == 0 and (2 * n) % (p * GC_CHUNK) == 0
    mc = (2 * n) // (p * GC_CHUNK)
    w = opts.worker
    chunks = []
    for c in range(mc):
        g = w * mc + c  # global chunk index in the combined sequence
        if g < n // GC_CHUNK:
            chunks.append(Integer(RECORD_W, GC_CHUNK)
                          .mark_input(Party.Garbler, A_TAGS + g))
        else:
            bidx = (2 * n // GC_CHUNK - 1) - g   # reversed chunk order
            v = Integer(RECORD_W, GC_CHUNK).mark_input(Party.Evaluator,
                                                       B_TAGS + bidx)
            chunks.append(v.reverse())
    out = distributed_merge_chunks(chunks, opts)
    output_chunks(out, OUT_TAGS + w * mc)


def _merge_inputs(n: int, worker: int, p: int):
    a = _records(n, seed=1000 + n, sort=True)
    b = _records(n, seed=2000 + n, sort=True)
    return _chunk_provider({A_TAGS: a, B_TAGS: b}, GC_CHUNK)


def _merge_oracle(n: int) -> dict[int, np.ndarray]:
    a = _records(n, seed=1000 + n, sort=True)
    b = _records(n, seed=2000 + n, sort=True)
    merged = _key_sort(np.concatenate([a, b]))
    return {OUT_TAGS + i: merged[i * GC_CHUNK:(i + 1) * GC_CHUNK]
            for i in range(2 * n // GC_CHUNK)}


register(Workload("merge", "gc", _merge_build, _merge_inputs, _merge_oracle,
                  page_shift=GC_PAGE_SHIFT, default_n=512))


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------


def _sort_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    p = opts.num_workers
    per = n // p
    base = opts.worker * (per // GC_CHUNK)
    chunks = [Integer(RECORD_W, GC_CHUNK).mark_input(Party.Garbler,
                                                     A_TAGS + base + i)
              for i in range(per // GC_CHUNK)]
    out = bitonic_sort_chunks(chunks, opts)
    output_chunks(out, OUT_TAGS + base)


def _sort_inputs(n: int, worker: int, p: int):
    data = _records(n, seed=3000 + n, sort=False)
    return _chunk_provider({A_TAGS: data}, GC_CHUNK)


def _sort_oracle(n: int) -> dict[int, np.ndarray]:
    data = _key_sort(_records(n, seed=3000 + n, sort=False))
    return {OUT_TAGS + i: data[i * GC_CHUNK:(i + 1) * GC_CHUNK]
            for i in range(n // GC_CHUNK)}


register(Workload("sort", "gc", _sort_build, _sort_inputs, _sort_oracle,
                  page_shift=GC_PAGE_SHIFT, default_n=512))


# ---------------------------------------------------------------------------
# ljoin (loop join: both inputs fit; the output, written in order, does not)
# ---------------------------------------------------------------------------

LJ_A_CELL = 8
LJ_B_CELL = 4


def _ljoin_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    p = opts.num_workers
    per = n // p                      # A rows per worker; B replicated
    a = input_chunks(per, Party.Garbler,
                     A_TAGS + opts.worker * (per // LJ_A_CELL),
                     chunk=LJ_A_CELL)
    b = input_chunks(n, Party.Evaluator, B_TAGS, chunk=LJ_B_CELL)
    base = OUT_TAGS + opts.worker * (per // LJ_A_CELL) * (n // LJ_B_CELL)
    # §8.1.3 three-phase discipline: the join output is MATERIALIZED in
    # memory (it is what exceeds the budget — "it is the output, populated
    # in order, that does not fit"), then written out in phase 3
    cells = []
    for ca in a:
        for cb in b:
            cells.append(ca.pair_join(cb, KEY_W))
    for t, cell in enumerate(cells):
        cell.mark_output(base + t)


def _ljoin_inputs(n: int, worker: int, p: int):
    a = _records(n, seed=4000 + n, sort=False)
    b = a.copy()
    rng = np.random.default_rng(4100 + n)
    rng.shuffle(b)                    # same key set, different order
    prov_a = _chunk_provider({A_TAGS: a}, LJ_A_CELL)
    prov_b = _chunk_provider({B_TAGS: b}, LJ_B_CELL)
    return lambda tag: prov_b(tag) if tag >= B_TAGS else prov_a(tag)


def _ljoin_oracle(n: int) -> dict[int, np.ndarray]:
    a = _records(n, seed=4000 + n, sort=False)
    b = a.copy()
    rng = np.random.default_rng(4100 + n)
    rng.shuffle(b)
    m = np.uint64((1 << 32) - 1)
    out: dict[int, np.ndarray] = {}
    t = 0
    kw, w = KEY_W, RECORD_W
    half = (w - kw) // 2
    hm = np.uint64((1 << half) - 1)
    for ia in range(n // LJ_A_CELL):
        ca = a[ia * LJ_A_CELL:(ia + 1) * LJ_A_CELL]
        for ib in range(n // LJ_B_CELL):
            cb = b[ib * LJ_B_CELL:(ib + 1) * LJ_B_CELL]
            aa = np.repeat(ca, LJ_B_CELL)
            bb = np.tile(cb, LJ_A_CELL)
            eq = (aa & m) == (bb & m)
            pa = (aa >> np.uint64(kw)) & hm
            pb = (bb >> np.uint64(kw)) & hm
            packed = (aa & m) | (pa << np.uint64(kw)) | (pb << np.uint64(kw + half))
            out[OUT_TAGS + t] = np.where(eq, packed & np.uint64((1 << 64) - 1),
                                         np.uint64(0))
            t += 1
    return out


register(Workload("ljoin", "gc", _ljoin_build, _ljoin_inputs, _ljoin_oracle,
                  page_shift=GC_PAGE_SHIFT, default_n=64))


# ---------------------------------------------------------------------------
# mvmul (8-bit integer matrix-vector)
# ---------------------------------------------------------------------------

MV_NR = 8     # rows per MAC cell
MV_NJ = 16    # cols per MAC cell


def _mvmul_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    p = opts.num_workers
    rows = n // p
    w = opts.worker
    vec = [Integer(8, MV_NJ).mark_input(Party.Evaluator, B_TAGS + j)
           for j in range(n // MV_NJ)]
    row_base = w * (rows // MV_NR)
    mat = [[Integer(8, MV_NR * MV_NJ).mark_input(
        Party.Garbler, A_TAGS + (row_base + r) * (n // MV_NJ) + j)
        for j in range(n // MV_NJ)] for r in range(rows // MV_NR)]
    zero = Integer(32, MV_NR)
    zero.builder.emit(  # public zero accumulator via a constant input
        Op.INPUT, outs=(zero.span,),
        imm=(MV_NR, 32, int(Party.Garbler), 1 << 28))
    outs = []
    for r in range(rows // MV_NR):
        acc = zero
        for j in range(n // MV_NJ):
            acc = mat[r][j].mac8(vec[j], acc)
        outs.append(acc)
    for r, acc in enumerate(outs):  # phase 3
        acc.mark_output(OUT_TAGS + row_base + r)


def _mvmul_data(n: int):
    rng = np.random.default_rng(5000 + n)
    M = rng.integers(0, 256, (n, n), dtype=np.uint64)
    v = rng.integers(0, 256, n, dtype=np.uint64)
    return M, v


def _mvmul_inputs(n: int, worker: int, p: int):
    M, v = _mvmul_data(n)

    def provider(tag: int) -> np.ndarray:
        if tag == 1 << 28:
            return np.zeros(MV_NR, dtype=np.uint64)
        if tag >= B_TAGS:
            j = tag - B_TAGS
            return v[j * MV_NJ:(j + 1) * MV_NJ]
        r, j = divmod(tag - A_TAGS, n // MV_NJ)
        blk = M[r * MV_NR:(r + 1) * MV_NR, j * MV_NJ:(j + 1) * MV_NJ]
        return blk.reshape(-1)
    return provider


def _mvmul_oracle(n: int) -> dict[int, np.ndarray]:
    M, v = _mvmul_data(n)
    res = (M * v[None, :]).sum(axis=1) & np.uint64(0xFFFFFFFF)
    return {OUT_TAGS + r: res[r * MV_NR:(r + 1) * MV_NR]
            for r in range(n // MV_NR)}


register(Workload("mvmul", "gc", _mvmul_build, _mvmul_inputs, _mvmul_oracle,
                  page_shift=GC_PAGE_SHIFT, default_n=64))


# ---------------------------------------------------------------------------
# binfclayer (XONN-style binary fully-connected layer)
# ---------------------------------------------------------------------------

BF_NR = 32
BF_NJ = 128


def _binfc_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    p = opts.num_workers
    rows = n // p
    w = opts.worker
    vec = [Integer(1, BF_NJ).mark_input(Party.Evaluator, B_TAGS + j)
           for j in range(n // BF_NJ)]
    row_base = w * (rows // BF_NR)
    # out[r] = sign(popcount_j xnor(M[r, :], v)): combine per-column-block
    # popcounts by adding counts — implemented as per-block sign is NOT
    # equivalent, so use one wide cell per row-block spanning all columns
    # when n == BF_NJ; otherwise accumulate counts via mac8-style adds.
    assert n % BF_NJ == 0
    # phase 1: the whole binary matrix is materialized (§8.1.3)
    mat = {}
    for r in range(rows // BF_NR):
        for j in range(max(n // BF_NJ, 1)):
            mat[(r, j)] = Integer(1, BF_NR * BF_NJ).mark_input(
                Party.Garbler, A_TAGS + (row_base + r) * (n // BF_NJ) + j)
    results = []
    for r in range(rows // BF_NR):
        if n == BF_NJ:
            results.append(mat[(r, 0)].xnor_pop_sign(vec[0], BF_NR))
        else:
            outs = [mat[(r, j)].xnor_pop_sign(vec[j], BF_NR)
                    for j in range(n // BF_NJ)]
            acc = outs[0]
            for o in outs[1:]:
                acc = acc ^ o  # parity combine (block-voting variant)
            results.append(acc)
    for r, out in enumerate(results):  # phase 3
        out.mark_output(OUT_TAGS + row_base + r)


def _binfc_data(n: int):
    rng = np.random.default_rng(6000 + n)
    M = rng.integers(0, 2, (n, n), dtype=np.uint64)
    v = rng.integers(0, 2, n, dtype=np.uint64)
    return M, v


def _binfc_inputs(n: int, worker: int, p: int):
    M, v = _binfc_data(n)

    def provider(tag: int) -> np.ndarray:
        if tag >= B_TAGS:
            j = tag - B_TAGS
            return v[j * BF_NJ:(j + 1) * BF_NJ]
        idx = tag - A_TAGS
        r, j = divmod(idx, max(n // BF_NJ, 1))
        blk = M[r * BF_NR:(r + 1) * BF_NR, j * BF_NJ:(j + 1) * BF_NJ]
        return blk.reshape(-1)
    return provider


def _binfc_oracle(n: int) -> dict[int, np.ndarray]:
    M, v = _binfc_data(n)
    out: dict[int, np.ndarray] = {}
    for r in range(n // BF_NR):
        rows = M[r * BF_NR:(r + 1) * BF_NR]
        if n == BF_NJ:
            cnt = (1 - (rows ^ v[None, :])).sum(axis=1)
            out[OUT_TAGS + r] = (cnt >= (n + 1) // 2).astype(np.uint64)
        else:
            acc = np.zeros(BF_NR, dtype=np.uint64)
            for j in range(n // BF_NJ):
                blk = rows[:, j * BF_NJ:(j + 1) * BF_NJ]
                vv = v[j * BF_NJ:(j + 1) * BF_NJ]
                cnt = (1 - (blk ^ vv[None, :])).sum(axis=1)
                acc ^= (cnt >= (BF_NJ + 1) // 2).astype(np.uint64)
            out[OUT_TAGS + r] = acc
    return out


register(Workload("binfclayer", "gc", _binfc_build, _binfc_inputs,
                  _binfc_oracle, page_shift=GC_PAGE_SHIFT, default_n=128))
