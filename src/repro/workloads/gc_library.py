"""Chunked oblivious sorting/merging library over the Integer DSL.

The §5.1 'easier-to-use DSL libraries' layer: bitonic networks expressed at
chunk granularity, optionally distributed across workers.  Remote pairs are
exchanged with network directives and compare-split locally (the classic
parallel bitonic construction) — this is what gives merge/sort their
mid-computation communication phases (Fig. 10).

Key structural fact used throughout: with mc chunks per worker, a bitonic
stage at chunk distance jc pairs chunk g with g^jc; when jc >= mc the
partner lives on worker w ^ (jc // mc) at the SAME local index, and the
low/high role is uniform across the stage — so sends and receives match in
FIFO order on both sides.
"""

from __future__ import annotations

from ..core.workers import ProgramOptions, recv_into, send_value
from ..protocols.garbled.dsl import Integer

RECORD_W = 128      # 32-bit key + payload (§8.1.1)
KEY_W = 32
GC_CHUNK = 32       # records per chunk: 32 * 128 wires = 4096 = one page


def input_chunks(n: int, party, tag_base: int, chunk: int = GC_CHUNK,
                 width: int = RECORD_W) -> list[Integer]:
    """Phase 1: materialize n records as n/chunk page-sized values."""
    assert n % chunk == 0
    return [Integer(width, chunk).mark_input(party, tag_base + i)
            for i in range(n // chunk)]


def output_chunks(chunks: list[Integer], tag_base: int) -> None:
    for i, c in enumerate(chunks):
        c.mark_output(tag_base + i)


def _cx(chunks, a: int, b: int, up: bool, key_w: int) -> None:
    mn, mx = chunks[a].minmax(chunks[b], key_w)
    chunks[a], chunks[b] = (mn, mx) if up else (mx, mn)


def _cx_remote(chunks, idx: int, keep_low: bool, partner: int,
               key_w: int) -> None:
    mine = chunks[idx]
    theirs = Integer(mine.width, mine.count)
    tag = send_value(mine, partner)
    recv_into(theirs, partner, tag)
    mn, mx = mine.minmax(theirs, key_w)
    chunks[idx] = mn if keep_low else mx


def _merge_stage(chunks: list[Integer], opts: ProgramOptions, k: int,
                 key_w: int, n_total: int) -> None:
    """One bitonic merge pass (block size k) over the global chunk sequence;
    ends with local merge_only finishes.  k and chunk counts: powers of 2."""
    mc = len(chunks)
    C = chunks[0].count
    w = opts.worker
    j = k // 2
    while j >= C:
        jc = j // C
        if jc >= mc:  # remote stage: uniform partner, same local index
            pw = w ^ (jc // mc)
            g0 = w * mc
            up = ((g0 * C) & k) == 0
            keep_low = up if pw > w else not up
            if pw > w:
                for c in range(mc):
                    _cx_remote(chunks, c, keep_low=keep_low, partner=pw,
                               key_w=key_w)
            else:
                up_partner = (((pw * mc) * C) & k) == 0
                for c in range(mc):
                    _cx_remote(chunks, c, keep_low=not up_partner,
                               partner=pw, key_w=key_w)
        else:
            for c in range(mc):
                partner = c ^ jc
                if partner > c:
                    g = w * mc + c
                    up = ((g * C) & k) == 0
                    _cx(chunks, c, partner, up, key_w)
        j //= 2
    for c in range(mc):
        g = w * mc + c
        up = ((g * C) & k) == 0
        chunks[c] = chunks[c].sort_local(key_w, descending=not up,
                                         merge_only=True)


def bitonic_sort_chunks(chunks: list[Integer], opts: ProgramOptions,
                        key_w: int = KEY_W) -> list[Integer]:
    """Ascending sort of the global sequence across all workers."""
    mc = len(chunks)
    C = chunks[0].count
    w, p = opts.worker, opts.num_workers
    n_total = mc * p * C
    assert (mc * p) & (mc * p - 1) == 0 and C & (C - 1) == 0

    # local sorts ≡ stages k=2..C of the element-level network: after stage
    # k=C each C-block is sorted ascending iff bit C of its base index is 0
    for c in range(mc):
        g = w * mc + c
        up = ((g * C) & C) == 0
        chunks[c] = chunks[c].sort_local(key_w, descending=not up)

    k = 2 * C
    while k <= n_total:
        _merge_stage(chunks, opts, k, key_w, n_total)
        k *= 2
    return chunks


def bitonic_merge_sorted_chunks(a: list[Integer], b: list[Integer],
                                opts: ProgramOptions,
                                key_w: int = KEY_W) -> list[Integer]:
    """Single-worker merge of two ascending-sorted chunk lists: reverse b
    (free wire shuffle), concatenate (bitonic), one merge pass."""
    assert opts.num_workers == 1
    C = a[0].count
    chunks = list(a) + [c.reverse() for c in reversed(b)]
    _merge_stage(chunks, opts, len(chunks) * C, key_w, len(chunks) * C)
    return chunks


def distributed_merge_chunks(chunks: list[Integer], opts: ProgramOptions,
                             key_w: int = KEY_W) -> list[Integer]:
    """Distributed merge: each worker already holds its block of the GLOBAL
    bitonic sequence [A asc, B desc] (input layout handled by the caller);
    one merge pass over all workers."""
    n_total = len(chunks) * opts.num_workers * chunks[0].count
    _merge_stage(chunks, opts, n_total, key_w, n_total)
    return chunks
