"""The ``aggsum`` workload: the secure-aggregation reduction as a MAGE
program, with a **vectorized trace builder**.

The DSL path traces one page-sized ``Integer`` vector per client and an
ADD chain over them — one Python ``Builder.emit`` (an ``Instr`` tuple)
per instruction, the cold-trace cost the ROADMAP flags.  Because the
program is *oblivious and regular*, its record stream is a closed form
of ``n``: every value is exactly one page, and full-page values get
strictly sequential pages from the slab allocator.  So
:func:`build_aggsum_records` emits the whole FREE-stripped trace as a
handful of NumPy column assignments into a ``[2n, RECORD_WORDS]``
record array — the ``pack_row`` layout without per-instruction Python —
and :func:`write_aggsum_program` streams it straight into a bytecode
file via ``ProgramWriter.append_records``.  ``tests/test_aggregate_
workload.py`` holds the two builders digest-identical.
"""

from __future__ import annotations

import numpy as np

from ..aggregate.offline import DEFAULT_SEED, client_vector
from ..core.bytecode import (_IMM_OFF, _IN_OFF, _OUT_OFF, RECORD_WORDS, Op,
                             ProgramFile, ProgramWriter)
from ..core.workers import ProgramOptions
from ..protocols.garbled.dsl import Integer, Party
from .base import GC_PAGE_SHIFT, Workload, register
from .gc_workloads import A_TAGS, OUT_TAGS

#: one client's contribution: 64 lanes of 64-bit — exactly one GC page
#: (64 * 64 = 4096 slots), so every DSL value is a full-page allocation
AGG_W = 64
AGG_VEC = 64


def _aggsum_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    assert opts.num_workers == 1, "aggsum is a single-worker reduction"
    vecs = [Integer(AGG_W, AGG_VEC).mark_input(Party.Garbler, A_TAGS + i)
            for i in range(n)]
    accs = [vecs[0]]                  # keep refs: no mid-build FREEs
    for v in vecs[1:]:
        accs.append(accs[-1] + v)
    accs[-1].mark_output(OUT_TAGS)


def build_aggsum_records(n: int) -> np.ndarray:
    """The FREE-stripped ``aggsum`` trace as a ``[2n, RECORD_WORDS]``
    record array, built with vectorized column writes.

    Layout mirrors the DSL exactly: inputs live on pages ``0..n-1``,
    accumulator ``k`` on page ``n+k-1`` (full-page values take fresh
    sequential pages), and the record fields are what ``Integer``'s
    emit calls produce for INPUT / ADD / OUTPUT."""
    if n <= 0:
        raise ValueError(f"aggsum needs n >= 1 clients, got {n}")
    page = 1 << GC_PAGE_SHIFT
    rec = np.zeros((2 * n, RECORD_WORDS), dtype=np.int64)

    # INPUT i: outs=((i*page, page),), imm=(count, width, party, tag)
    i = np.arange(n, dtype=np.int64)
    rec[:n, 0] = int(Op.INPUT) | 1 << 16 | 4 << 24
    rec[:n, _OUT_OFF] = i * page
    rec[:n, _OUT_OFF + 1] = page
    rec[:n, _IMM_OFF] = AGG_VEC
    rec[:n, _IMM_OFF + 1] = AGG_W
    rec[:n, _IMM_OFF + 2] = int(Party.Garbler)
    rec[:n, _IMM_OFF + 3] = A_TAGS + i

    # ADD k: acc_k = acc_{k-1} + vec_k (acc_0 IS vec_0), k = 1..n-1
    if n > 1:
        k = np.arange(1, n, dtype=np.int64)
        add = rec[n:2 * n - 1]
        add[:, 0] = int(Op.ADD) | 1 << 16 | 2 << 20 | 2 << 24
        add[:, _OUT_OFF] = (n + k - 1) * page
        add[:, _OUT_OFF + 1] = page
        add[:, _IN_OFF] = np.where(k == 1, 0, (n + k - 2) * page)
        add[:, _IN_OFF + 1] = page
        add[:, _IN_OFF + 2] = k * page
        add[:, _IN_OFF + 3] = page
        add[:, _IMM_OFF] = AGG_VEC
        add[:, _IMM_OFF + 1] = AGG_W

    # OUTPUT: ins=(final acc,), imm=(count, width, tag)
    out = rec[2 * n - 1]
    out[0] = int(Op.OUTPUT) | 1 << 20 | 3 << 24
    out[_IN_OFF] = (2 * n - 2) * page if n > 1 else 0
    out[_IN_OFF + 1] = page
    out[_IMM_OFF] = AGG_VEC
    out[_IMM_OFF + 1] = AGG_W
    out[_IMM_OFF + 2] = OUT_TAGS
    return rec


def write_aggsum_program(path, n: int) -> ProgramFile:
    """Stream the vectorized trace straight to a bytecode file — the
    fast cold-trace path (no Instr objects, no allocator)."""
    pages = 2 * n - 1 if n > 1 else 1
    w = ProgramWriter(path, page_shift=GC_PAGE_SHIFT, protocol="gc",
                      vspace_slots=pages << GC_PAGE_SHIFT,
                      meta={"workload": "aggsum", "n": n})
    w.append_records(build_aggsum_records(n))
    return w.close()


def _aggsum_inputs(n: int, worker: int, p: int):
    def provider(tag: int) -> np.ndarray:
        return client_vector(DEFAULT_SEED, tag - A_TAGS, 0, AGG_VEC)
    return provider


def _aggsum_oracle(n: int) -> dict[int, np.ndarray]:
    total = np.zeros(AGG_VEC, dtype=np.uint64)
    for c in range(n):
        total += client_vector(DEFAULT_SEED, c, 0, AGG_VEC)
    return {OUT_TAGS: total}


register(Workload("aggsum", "gc", _aggsum_build, _aggsum_inputs,
                  _aggsum_oracle, page_shift=GC_PAGE_SHIFT, default_n=64))
