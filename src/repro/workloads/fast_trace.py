"""Vectorized trace builders for the §8.1.1 GC workloads (single worker).

Same idea as :mod:`.agg_workload`'s ``build_aggsum_records``, applied to
the bitonic workloads: because the programs are *oblivious*, the record
stream of ``merge``/``sort``/``mvmul`` is a function of ``n`` alone, so
it can be assembled with NumPy column writes — one Python iteration per
*network stage* (O(log^2 n) of them) instead of one ``Builder.emit`` per
instruction.  The builders are digest-identical to the FREE-stripped DSL
trace (held by ``tests/test_fast_trace.py``), which makes them drop-in
cold-trace accelerators and, just as importantly, an executable spec of
the DSL's allocation behaviour:

* merge/sort touch only page-sized values (one ``GC_CHUNK`` record chunk
  = 4096 slots = one page), and :class:`~..core.placement.PageAllocator`
  gives page-sized values dedicated, strictly sequential pages — mid-
  build FREEs never perturb addresses, so pages are a running counter.
* mvmul's 256-slot accumulators live in a slab class whose addresses DO
  depend on the free/alloc interleaving; those allocations replay
  through a real ``PageAllocator`` in exactly the DSL's order (a few
  thousand trivial calls), while the record columns stay vectorized.

``write_*_program`` stream the records straight into a bytecode file via
``ProgramWriter.append_records`` — no ``Instr`` objects, no DSL.
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import (_IMM_OFF, _IN_OFF, _OUT_OFF, RECORD_WORDS, Op,
                             ProgramFile, ProgramWriter)
from ..core.placement import PageAllocator
from ..protocols.garbled.dsl import Party
from .base import GC_PAGE_SHIFT, Workload, register  # noqa: F401  (base dep)
from .gc_library import GC_CHUNK, KEY_W, RECORD_W
from .gc_workloads import A_TAGS, B_TAGS, OUT_TAGS, MV_NJ, MV_NR

_PAGE = 1 << GC_PAGE_SHIFT


def _word0(op: Op, n_outs: int, n_ins: int, n_imm: int) -> int:
    return int(op) | n_outs << 16 | n_ins << 20 | n_imm << 24


def _rows(n: int) -> np.ndarray:
    return np.zeros((n, RECORD_WORDS), dtype=np.int64)


def _inputs(pages: np.ndarray, party: Party, tags: np.ndarray,
            count: int = GC_CHUNK, width: int = RECORD_W) -> np.ndarray:
    """INPUT records for page-sized chunks at the given pages."""
    r = _rows(len(pages))
    r[:, 0] = _word0(Op.INPUT, 1, 0, 4)
    r[:, _OUT_OFF] = pages * _PAGE
    r[:, _OUT_OFF + 1] = _PAGE
    r[:, _IMM_OFF] = count
    r[:, _IMM_OFF + 1] = width
    r[:, _IMM_OFF + 2] = int(party)
    r[:, _IMM_OFF + 3] = tags
    return r


def _outputs(addrs: np.ndarray, tags: np.ndarray,
             count: int = GC_CHUNK, width: int = RECORD_W,
             nbytes: int = _PAGE) -> np.ndarray:
    r = _rows(len(addrs))
    r[:, 0] = _word0(Op.OUTPUT, 0, 1, 3)
    r[:, _IN_OFF] = addrs
    r[:, _IN_OFF + 1] = nbytes
    r[:, _IMM_OFF] = count
    r[:, _IMM_OFF + 1] = width
    r[:, _IMM_OFF + 2] = tags
    return r


def _sort_locals(in_addrs: np.ndarray, next_page: int, descending,
                 merge_only: bool) -> tuple[np.ndarray, np.ndarray, int]:
    """SORT_LOCAL per chunk; returns (records, new addrs, next_page)."""
    m = len(in_addrs)
    out = (next_page + np.arange(m, dtype=np.int64)) * _PAGE
    r = _rows(m)
    r[:, 0] = _word0(Op.SORT_LOCAL, 1, 1, 5)
    r[:, _OUT_OFF] = out
    r[:, _OUT_OFF + 1] = _PAGE
    r[:, _IN_OFF] = in_addrs
    r[:, _IN_OFF + 1] = _PAGE
    r[:, _IMM_OFF] = GC_CHUNK
    r[:, _IMM_OFF + 1] = RECORD_W
    r[:, _IMM_OFF + 2] = KEY_W
    r[:, _IMM_OFF + 3] = descending
    r[:, _IMM_OFF + 4] = int(merge_only)
    return r, out, next_page + m


def _merge_pass(chunk_addr: np.ndarray, k: int, next_page: int,
                out: list[np.ndarray]) -> int:
    """One ``_merge_stage`` (block size ``k`` slots) over the chunk
    sequence: MINMAX stages at chunk distance jc = k/2C .. 1, then the
    merge-only local finishes.  Mutates ``chunk_addr`` in place."""
    m = len(chunk_addr)
    cs_all = np.arange(m, dtype=np.int64)
    up_all = ((cs_all * GC_CHUNK) & k) == 0
    jc = min(k // (2 * GC_CHUNK), m // 2)
    while jc >= 1:
        cs = cs_all[(cs_all & jc) == 0]          # emission order: c asc
        ps = cs ^ jc
        up = up_all[cs]
        r = _rows(len(cs))
        mn = (next_page + 2 * np.arange(len(cs), dtype=np.int64)) * _PAGE
        mx = mn + _PAGE                           # mn allocated before mx
        next_page += 2 * len(cs)
        r[:, 0] = _word0(Op.MINMAX, 2, 2, 3)
        r[:, _OUT_OFF] = mn
        r[:, _OUT_OFF + 1] = _PAGE
        r[:, _OUT_OFF + 2] = mx
        r[:, _OUT_OFF + 3] = _PAGE
        r[:, _IN_OFF] = chunk_addr[cs]
        r[:, _IN_OFF + 1] = _PAGE
        r[:, _IN_OFF + 2] = chunk_addr[ps]
        r[:, _IN_OFF + 3] = _PAGE
        r[:, _IMM_OFF] = GC_CHUNK
        r[:, _IMM_OFF + 1] = RECORD_W
        r[:, _IMM_OFF + 2] = KEY_W
        out.append(r)
        chunk_addr[cs] = np.where(up, mn, mx)
        chunk_addr[ps] = np.where(up, mx, mn)
        jc //= 2
    r, addrs, next_page = _sort_locals(chunk_addr, next_page,
                                       (~up_all).astype(np.int64), True)
    out.append(r)
    chunk_addr[:] = addrs
    return next_page


def build_merge_records(n: int) -> np.ndarray:
    """The FREE-stripped single-worker ``merge`` trace for ``n`` records
    per party, as one ``[*, RECORD_WORDS]`` array."""
    q, rem = divmod(n, GC_CHUNK)
    m = 2 * q
    if rem or q <= 0 or m & (m - 1):
        raise ValueError(f"merge needs n a chunk multiple with 2n/{GC_CHUNK} "
                         f"a power of two, got n={n}")
    i = np.arange(q, dtype=np.int64)
    out = [_inputs(i, Party.Garbler, A_TAGS + i),
           _inputs(q + i, Party.Evaluator, B_TAGS + i)]
    # [c.reverse() for c in reversed(b)]: in page 2q-1-j -> out page 2q+j
    rev = _rows(q)
    rev[:, 0] = _word0(Op.REVERSE, 1, 1, 2)
    rev[:, _OUT_OFF] = (2 * q + i) * _PAGE
    rev[:, _OUT_OFF + 1] = _PAGE
    rev[:, _IN_OFF] = (2 * q - 1 - i) * _PAGE
    rev[:, _IN_OFF + 1] = _PAGE
    rev[:, _IMM_OFF] = GC_CHUNK
    rev[:, _IMM_OFF + 1] = RECORD_W
    out.append(rev)
    chunk_addr = np.concatenate([i * _PAGE, (2 * q + i) * _PAGE])
    next_page = _merge_pass(chunk_addr, m * GC_CHUNK, 3 * q, out)
    c = np.arange(m, dtype=np.int64)
    out.append(_outputs(chunk_addr, OUT_TAGS + c))
    return np.vstack(out)


def build_sort_records(n: int) -> np.ndarray:
    """The FREE-stripped single-worker ``sort`` trace for ``n`` records."""
    q, rem = divmod(n, GC_CHUNK)
    if rem or q <= 0 or q & (q - 1):
        raise ValueError(f"sort needs n a power-of-two multiple of "
                         f"{GC_CHUNK}, got n={n}")
    c = np.arange(q, dtype=np.int64)
    out = [_inputs(c, Party.Garbler, A_TAGS + c)]
    # initial local sorts: ascending iff bit C of the chunk base is clear
    desc = (((c * GC_CHUNK) & GC_CHUNK) != 0).astype(np.int64)
    r, chunk_addr, next_page = _sort_locals(c * _PAGE, q, desc, False)
    out.append(r)
    k = 2 * GC_CHUNK
    while k <= n:
        next_page = _merge_pass(chunk_addr, k, next_page, out)
        k *= 2
    out.append(_outputs(chunk_addr, OUT_TAGS + c))
    return np.vstack(out)


def build_mvmul_records(n: int) -> np.ndarray:
    """The FREE-stripped single-worker ``mvmul`` trace for an n x n
    8-bit matrix.  Accumulators are 256-slot slab values whose addresses
    depend on the DSL's alloc/free interleaving, so those replay through
    a real :class:`PageAllocator`; everything else is closed-form."""
    if n <= 0 or n % MV_NJ or n % MV_NR:
        raise ValueError(f"mvmul needs n a multiple of {MV_NJ}, got n={n}")
    J, R = n // MV_NJ, n // MV_NR
    alloc = PageAllocator(GC_PAGE_SHIFT)
    vec = np.fromiter((alloc.alloc(8 * MV_NJ) for _ in range(J)),
                      dtype=np.int64, count=J)
    mat = np.fromiter((alloc.alloc(8 * MV_NR * MV_NJ) for _ in range(R * J)),
                      dtype=np.int64, count=R * J).reshape(R, J)
    zero = alloc.alloc(32 * MV_NR)

    j = np.arange(J, dtype=np.int64)
    out = [_rows(J)]
    out[0][:, 0] = _word0(Op.INPUT, 1, 0, 4)
    out[0][:, _OUT_OFF] = vec
    out[0][:, _OUT_OFF + 1] = 8 * MV_NJ
    out[0][:, _IMM_OFF] = MV_NJ
    out[0][:, _IMM_OFF + 1] = 8
    out[0][:, _IMM_OFF + 2] = int(Party.Evaluator)
    out[0][:, _IMM_OFF + 3] = B_TAGS + j
    mi = _rows(R * J)
    mi[:, 0] = _word0(Op.INPUT, 1, 0, 4)
    mi[:, _OUT_OFF] = mat.reshape(-1)
    mi[:, _OUT_OFF + 1] = 8 * MV_NR * MV_NJ
    mi[:, _IMM_OFF] = MV_NR * MV_NJ
    mi[:, _IMM_OFF + 1] = 8
    mi[:, _IMM_OFF + 2] = int(Party.Garbler)
    mi[:, _IMM_OFF + 3] = A_TAGS + np.arange(R * J, dtype=np.int64)
    out.append(mi)
    zi = _rows(1)
    zi[0, 0] = _word0(Op.INPUT, 1, 0, 4)
    zi[0, _OUT_OFF] = zero
    zi[0, _OUT_OFF + 1] = 32 * MV_NR
    zi[0, _IMM_OFF] = MV_NR
    zi[0, _IMM_OFF + 1] = 32
    zi[0, _IMM_OFF + 2] = int(Party.Garbler)
    zi[0, _IMM_OFF + 3] = 1 << 28
    out.append(zi)

    # acc chains: r's new acc allocs before the previous one frees (the
    # rebinding in `acc = mat[r][j].mac8(vec[j], acc)` drops the old ref
    # only after mac8 returns); finals stay live until the OUTPUT phase
    finals = np.empty(R, dtype=np.int64)
    accs = np.empty((R, J + 1), dtype=np.int64)
    for r in range(R):
        prev = zero
        for jj in range(J):
            cur = alloc.alloc(32 * MV_NR)
            accs[r, jj] = prev
            accs[r, jj + 1] = cur
            if prev != zero:
                alloc.free(prev)
            prev = cur
        finals[r] = prev
    mac = _rows(R * J)
    mac[:, 0] = _word0(Op.MAC8, 1, 3, 3)
    mac[:, _OUT_OFF] = accs[:, 1:].reshape(-1)
    mac[:, _OUT_OFF + 1] = 32 * MV_NR
    mac[:, _IN_OFF] = mat.reshape(-1)
    mac[:, _IN_OFF + 1] = 8 * MV_NR * MV_NJ
    mac[:, _IN_OFF + 2] = np.tile(vec, R)
    mac[:, _IN_OFF + 3] = 8 * MV_NJ
    mac[:, _IN_OFF + 4] = accs[:, :-1].reshape(-1)
    mac[:, _IN_OFF + 5] = 32 * MV_NR
    mac[:, _IMM_OFF] = MV_NR
    mac[:, _IMM_OFF + 1] = MV_NJ
    mac[:, _IMM_OFF + 2] = 32
    out.append(mac)
    out.append(_outputs(finals, OUT_TAGS + np.arange(R, dtype=np.int64),
                        count=MV_NR, width=32, nbytes=32 * MV_NR))
    return np.vstack(out)


def _write(path, name: str, n: int, rec: np.ndarray,
           pages: int) -> ProgramFile:
    w = ProgramWriter(path, page_shift=GC_PAGE_SHIFT, protocol="gc",
                      vspace_slots=pages << GC_PAGE_SHIFT,
                      meta={"workload": name, "n": n})
    w.append_records(rec)
    return w.close()


def write_merge_program(path, n: int) -> ProgramFile:
    rec = build_merge_records(n)
    pages = int(rec[:, _OUT_OFF].max()) // _PAGE + 1
    return _write(path, "merge", n, rec, pages)


def write_sort_program(path, n: int) -> ProgramFile:
    rec = build_sort_records(n)
    pages = int(rec[:, _OUT_OFF].max()) // _PAGE + 1
    return _write(path, "sort", n, rec, pages)


def write_mvmul_program(path, n: int) -> ProgramFile:
    rec = build_mvmul_records(n)
    top = int(max(rec[:, _OUT_OFF].max(), rec[:, _IN_OFF].max()))
    return _write(path, "mvmul", n, rec, top // _PAGE + 1)
