"""§8.8 applications: password-reuse detection (GC) and computational PIR
(CKKS, Kushilevitz–Ostrovsky sqrt-scheme)."""

from __future__ import annotations

import math

import numpy as np

from ..core.bytecode import Op
from ..core.workers import ProgramOptions
from ..protocols.ckks import Batch, Plain
from ..protocols.garbled.dsl import Integer, Party
from .base import CKKS_PAGE_SHIFT, GC_PAGE_SHIFT, Workload, register
from .ckks_workloads import PARAMS, _provider
from .gc_library import GC_CHUNK, RECORD_W, bitonic_merge_sorted_chunks

A_TAGS = 0
B_TAGS = 1 << 20
Q_TAGS = 1 << 21
OUT_TAGS = 1 << 24
MATCH_KEY_W = 64          # uid (32b) + password hash (32b)


# ---------------------------------------------------------------------------
# Password-reuse detection (Senate Query 2): merge by (uid, hash), then flag
# adjacent duplicates.
# ---------------------------------------------------------------------------


def _passreuse_build(opts: ProgramOptions) -> None:
    n = opts.problem_size
    a = [Integer(RECORD_W, GC_CHUNK).mark_input(Party.Garbler, A_TAGS + i)
         for i in range(n // GC_CHUNK)]
    b = [Integer(RECORD_W, GC_CHUNK).mark_input(Party.Evaluator, B_TAGS + i)
         for i in range(n // GC_CHUNK)]
    merged = bitonic_merge_sorted_chunks(a, b, opts, key_w=MATCH_KEY_W)
    bld = merged[0].builder
    prev = None
    for i, cur in enumerate(merged):
        shifted = Integer(RECORD_W, GC_CHUNK)
        if prev is None:  # first element compares against itself -> no match
            bld.emit(Op.COPY,
                     outs=((shifted.addr, RECORD_W),),
                     ins=((cur.addr, RECORD_W),))
        else:
            bld.emit(Op.COPY,
                     outs=((shifted.addr, RECORD_W),),
                     ins=((prev.addr + (GC_CHUNK - 1) * RECORD_W, RECORD_W),))
        bld.emit(Op.COPY,
                 outs=((shifted.addr + RECORD_W, (GC_CHUNK - 1) * RECORD_W),),
                 ins=((cur.addr, (GC_CHUNK - 1) * RECORD_W),))
        eq = cur.cmp_eq(shifted, key_w=MATCH_KEY_W)
        if prev is None:
            # lane 0 of the first chunk compared against itself: mask it off
            mask = Integer(1, GC_CHUNK)
            bld.emit(Op.INPUT, outs=(mask.span,),
                     imm=(GC_CHUNK, 1, int(Party.Garbler), 1 << 28))
            eq = eq & mask
        eq.mark_output(OUT_TAGS + i)
        prev = cur


def _passreuse_data(n: int):
    rng = np.random.default_rng(8000 + n)
    uids = rng.integers(0, n * 4, 2 * n, dtype=np.uint64)
    hashes = rng.integers(0, 1 << 16, 2 * n, dtype=np.uint64)
    rec = (uids | (hashes << np.uint64(32)))
    a = np.sort(rec[:n])
    b = np.sort(rec[n:])
    # force some collisions
    b[: n // 4] = a[: n // 4]
    b = np.sort(b)
    return a, b


def _passreuse_inputs(n: int, worker: int, p: int):
    a, b = _passreuse_data(n)

    def provider(tag: int) -> np.ndarray:
        if tag == 1 << 28:
            m = np.ones(GC_CHUNK, dtype=np.uint64)
            m[0] = 0
            return m
        if tag >= B_TAGS:
            i = tag - B_TAGS
            return b[i * GC_CHUNK:(i + 1) * GC_CHUNK]
        i = tag - A_TAGS
        return a[i * GC_CHUNK:(i + 1) * GC_CHUNK]
    return provider


def _passreuse_oracle(n: int) -> dict[int, np.ndarray]:
    a, b = _passreuse_data(n)
    merged = np.sort(np.concatenate([a, b]), kind="stable")
    eq = np.zeros(2 * n, dtype=np.uint64)
    eq[1:] = (merged[1:] == merged[:-1]).astype(np.uint64)
    return {OUT_TAGS + i: eq[i * GC_CHUNK:(i + 1) * GC_CHUNK]
            for i in range(2 * n // GC_CHUNK)}


register(Workload("passreuse", "gc", _passreuse_build, _passreuse_inputs,
                  _passreuse_oracle, page_shift=GC_PAGE_SHIFT, default_n=256))


# ---------------------------------------------------------------------------
# Computational PIR (KO97 sqrt scheme over CKKS)
# ---------------------------------------------------------------------------


def _pir_grid(n: int) -> tuple[int, int]:
    r = 1 << max(0, math.isqrt(n - 1).bit_length())
    while r * r < n:
        r *= 2
    return r, (n + r - 1) // r


def _pir_build(opts: ProgramOptions) -> None:
    p = PARAMS if "ckks_params" not in opts.extra else opts.extra["ckks_params"]
    n = opts.problem_size
    r, c = _pir_grid(n)
    cols = c // opts.num_workers if c % opts.num_workers == 0 else c
    k0 = opts.worker * cols if opts.num_workers > 1 and c % opts.num_workers == 0 else 0
    if opts.num_workers == 1:
        k0, cols = 0, c
    # phase 1: materialize the (plaintext-encoded) database + query
    db = {(i, k): Plain(p).mark_input(A_TAGS + i * c + k)
          for i in range(r) for k in range(k0, k0 + cols)}
    q = [Batch(p).mark_input(Q_TAGS + i) for i in range(r)]
    # phase 2: linear scan — one column accumulator per output
    for k in range(k0, k0 + cols):
        acc = q[0].mul_plain(db[(0, k)])
        for i in range(1, r):
            acc = acc + q[i].mul_plain(db[(i, k)])
        acc.mark_output(OUT_TAGS + k)


def _pir_data(n: int):
    rng = np.random.default_rng(8200 + n)
    r, c = _pir_grid(n)
    db = rng.uniform(-1, 1, (r * c, PARAMS.slots))
    target = int(rng.integers(0, r))
    q = np.zeros((r, PARAMS.slots))
    q[target] = 1.0
    return db, q, target


def _pir_inputs(n: int, worker: int, p: int):
    db, q, _ = _pir_data(n)
    return _provider({A_TAGS: db, Q_TAGS: q})


def _pir_oracle(n: int) -> dict[int, np.ndarray]:
    db, q, target = _pir_data(n)
    r, c = _pir_grid(n)
    return {OUT_TAGS + k: db[target * c + k] for k in range(c)}


register(Workload("pir", "ckks", _pir_build, _pir_inputs, _pir_oracle,
                  page_shift=CKKS_PAGE_SHIFT, default_n=64))
