"""Round-structured n-party Shamir workloads + vectorized trace builders.

Two workload families over GF(2^61 - 1), both parameterized by the party
count through ``num_workers`` (the n Shamir parties ARE the n workers,
see docs/SHAMIR.md):

* ``shamir_stats`` — threshold statistics over B = n / 256 secret blocks:
  sum, mean (sum * B^-1) and variance (E[x^2] - mean^2).  The B
  elementwise squares are *independent* multiplication rounds inside one
  barrier-free window — the communication shape the overlap pass hides.
* ``shamir_cmp`` — an equality-comparison tree: leaf differences
  x_b - y_b, a log-depth multiplication tree (the root is 0 iff any leaf
  pair is equal), and a Fermat zero-test chain z^(p-1) — a deep
  sequential round structure (~119 dependent MULs).

Like ``fast_trace`` for the GC kernels, each family also has a
vectorized NumPy record builder that is digest-identical to the
FREE-stripped DSL trace (held by ``tests/test_shamir.py``).  Shamir
traces pin every value until the trace closes, so allocation is a
strictly sequential page counter and the whole layout is closed-form:
the only Python-level iteration is one loop per *round batch* (tree
level / Fermat step).
"""

from __future__ import annotations

import numpy as np

from ..core.bytecode import (_IMM_OFF, _IN_OFF, _OUT_OFF, RECORD_WORDS, Op,
                             ProgramFile, ProgramWriter)
from ..core.workers import ProgramOptions
from ..protocols.shamir.dsl import (ROUND_TAG, REVEAL_TAG, Shared, mul,
                                    reveal, share_input)
from ..protocols.shamir.field import (P, addmod, fold, inverse,
                                      lagrange_at_zero, mulmod,
                                      mulmod_scalar, submod)
from .base import Workload, register

SH_PAGE_SHIFT = 8          # 256 uint64 slots = 2 KiB pages
SH_VEC = 1 << SH_PAGE_SHIFT   # one full-page vector per secret block

A_TAGS = 0
B_TAGS = 1 << 20
OUT_TAGS = 1 << 24


def _blocks(n: int, lo: int = 1) -> int:
    b, rem = divmod(n, SH_VEC)
    if rem or b < lo:
        raise ValueError(f"shamir workloads need n a multiple of {SH_VEC} "
                         f"with at least {lo} blocks, got n={n}")
    return b


def _provider(data_by_base: dict[int, np.ndarray]):
    def provider(tag: int) -> np.ndarray:
        for base, data in data_by_base.items():
            if base <= tag < base + (1 << 20):
                i = tag - base
                return data[i * SH_VEC:(i + 1) * SH_VEC]
        raise KeyError(tag)
    return provider


def _stats_data(n: int) -> np.ndarray:
    rng = np.random.default_rng(7000 + n)
    return rng.integers(0, P, n, dtype=np.uint64)


def _cmp_data(n: int) -> tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(8000 + n)
    x = rng.integers(0, P, n, dtype=np.uint64)
    d = rng.integers(1, P, n, dtype=np.uint64)    # never 0: lanes differ
    d[:SH_VEC // 2] = 0                           # block 0, low lanes: equal
    return x, addmod(x, d)


# ---------------------------------------------------------------------------
# shamir_stats
# ---------------------------------------------------------------------------


def _stats_build(opts: ProgramOptions) -> None:
    b = _blocks(opts.problem_size)
    xs = [share_input(SH_VEC, A_TAGS + i) for i in range(b)]
    s = xs[0]
    for i in range(1, b):
        s = s + xs[i]
    inv_b = inverse(b)
    m = s.mulc(inv_b)
    sqs = [mul(x, x) for x in xs]
    q = sqs[0]
    for i in range(1, b):
        q = q + sqs[i]
    msq = q.mulc(inv_b)
    var = msq - mul(m, m)
    reveal(s, 0, OUT_TAGS + 0)
    reveal(m, 1, OUT_TAGS + 1)
    reveal(var, 2, OUT_TAGS + 2)


def _stats_inputs(n: int, worker: int, p: int):
    return _provider({A_TAGS: _stats_data(n)})


def _stats_oracle(n: int) -> dict[int, np.ndarray]:
    b = _blocks(n)
    x = fold(_stats_data(n)).reshape(b, SH_VEC)
    s = np.zeros(SH_VEC, dtype=np.uint64)
    sq = np.zeros(SH_VEC, dtype=np.uint64)
    for i in range(b):
        s = addmod(s, x[i])
        sq = addmod(sq, mulmod(x[i], x[i]))
    inv_b = inverse(b)
    m = mulmod_scalar(s, inv_b)
    var = submod(mulmod_scalar(sq, inv_b), mulmod(m, m))
    return {OUT_TAGS + 0: s, OUT_TAGS + 1: m, OUT_TAGS + 2: var}


register(Workload("shamir_stats", "shamir", _stats_build, _stats_inputs,
                  _stats_oracle, page_shift=SH_PAGE_SHIFT, default_n=2048))


# ---------------------------------------------------------------------------
# shamir_cmp
# ---------------------------------------------------------------------------


def _cmp_build(opts: ProgramOptions) -> None:
    b = _blocks(opts.problem_size, lo=2)
    xs, ys = [], []
    for i in range(b):
        xs.append(share_input(SH_VEC, A_TAGS + i))
        ys.append(share_input(SH_VEC, B_TAGS + i))
    level = [x - y for x, y in zip(xs, ys)]
    while len(level) > 1:
        nxt = [mul(level[i], level[i + 1])
               for i in range(0, len(level) - 1, 2)]
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    root = level[0]
    acc = root                                   # Fermat: root^(p-1)
    for bit in bin(P - 1)[3:]:                   # MSB consumed by acc=root
        acc = mul(acc, acc)
        if bit == "1":
            acc = mul(acc, root)
    reveal(acc, 0, OUT_TAGS + 0)


def _cmp_inputs(n: int, worker: int, p: int):
    x, y = _cmp_data(n)
    return _provider({A_TAGS: x, B_TAGS: y})


def _cmp_oracle(n: int) -> dict[int, np.ndarray]:
    b = _blocks(n, lo=2)
    x, y = _cmp_data(n)
    z = submod(fold(x), fold(y)).reshape(b, SH_VEC)
    prod = z[0]
    for i in range(1, b):
        prod = mulmod(prod, z[i])
    return {OUT_TAGS + 0: np.where(prod == 0, 0, 1).astype(np.uint64)}


register(Workload("shamir_cmp", "shamir", _cmp_build, _cmp_inputs,
                  _cmp_oracle, page_shift=SH_PAGE_SHIFT, default_n=1024))


# ---------------------------------------------------------------------------
# vectorized record builders (digest-identical to the DSL trace)
# ---------------------------------------------------------------------------

_PAGE = SH_VEC


def _word0(op: Op, n_outs: int, n_ins: int, n_imm: int) -> int:
    return int(op) | n_outs << 16 | n_ins << 20 | n_imm << 24


def _rows(n: int) -> np.ndarray:
    return np.zeros((n, RECORD_WORDS), dtype=np.int64)


class _Rec:
    """Sequential-page record emitter mirroring the shamir DSL layout."""

    def __init__(self, worker: int, num_workers: int):
        if num_workers < 3:
            raise ValueError(f"shamir traces need num_workers >= 3, "
                             f"got {num_workers}")
        self.w = worker
        self.n = num_workers
        self.t = (num_workers - 1) // 2
        self.lam = lagrange_at_zero(num_workers)
        self.page = 0          # the DSL's strictly sequential page counter
        self.rid = 0
        self.out: list[np.ndarray] = []

    def pages(self, k: int) -> np.ndarray:
        """Allocate k sequential pages; returns their slot addresses."""
        a = (self.page + np.arange(k, dtype=np.int64)) * _PAGE
        self.page += k
        return a

    def inputs(self, tags: np.ndarray) -> np.ndarray:
        r = _rows(len(tags))
        addr = self.pages(len(tags))
        r[:, 0] = _word0(Op.INPUT, 1, 0, 2)
        r[:, _OUT_OFF] = addr
        r[:, _OUT_OFF + 1] = _PAGE
        r[:, _IMM_OFF] = SH_VEC
        r[:, _IMM_OFF + 1] = tags
        self.out.append(r)
        return addr

    def _bin(self, op: Op, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        r = _rows(len(a))
        addr = self.pages(len(a))
        r[:, 0] = _word0(op, 1, 2, 1)
        r[:, _OUT_OFF] = addr
        r[:, _OUT_OFF + 1] = _PAGE
        r[:, _IN_OFF] = a
        r[:, _IN_OFF + 1] = _PAGE
        r[:, _IN_OFF + 2] = b
        r[:, _IN_OFF + 3] = _PAGE
        r[:, _IMM_OFF] = SH_VEC
        self.out.append(r)
        return addr

    def add_chain(self, addrs: np.ndarray) -> int:
        """((a0+a1)+a2)... left fold; returns the final address."""
        acc = int(addrs[0])
        if len(addrs) > 1:
            outs = self.page * _PAGE + \
                np.arange(len(addrs) - 1, dtype=np.int64) * _PAGE
            prev = np.concatenate(([acc], outs[:-1]))
            r = _rows(len(addrs) - 1)
            self.pages(len(addrs) - 1)
            r[:, 0] = _word0(Op.F_ADD, 1, 2, 1)
            r[:, _OUT_OFF] = outs
            r[:, _OUT_OFF + 1] = _PAGE
            r[:, _IN_OFF] = prev
            r[:, _IN_OFF + 1] = _PAGE
            r[:, _IN_OFF + 2] = addrs[1:]
            r[:, _IN_OFF + 3] = _PAGE
            r[:, _IMM_OFF] = SH_VEC
            self.out.append(r)
            acc = int(outs[-1])
        return acc

    def mulc(self, a: int, c: int) -> int:
        r = _rows(1)
        addr = int(self.pages(1)[0])
        r[0, 0] = _word0(Op.F_MULC, 1, 1, 2)
        r[0, _OUT_OFF] = addr
        r[0, _OUT_OFF + 1] = _PAGE
        r[0, _IN_OFF] = a
        r[0, _IN_OFF + 1] = _PAGE
        r[0, _IMM_OFF] = SH_VEC
        r[0, _IMM_OFF + 1] = c % P
        self.out.append(r)
        return addr

    def sub(self, a: int, b: int) -> int:
        return int(self._bin(Op.F_SUB, np.array([a], dtype=np.int64),
                             np.array([b], dtype=np.int64))[0])

    def mul_rounds(self, xa: np.ndarray, ya: np.ndarray) -> np.ndarray:
        """A batch of R independent degree-reduction rounds (the DSL's
        ``mul``), emitted round-major; returns the R result addresses."""
        n, w, t = self.n, self.w, self.t
        big = np.int64(_PAGE)
        xa = np.asarray(xa, dtype=np.int64)
        ya = np.asarray(ya, dtype=np.int64)
        rr = len(xa)
        rpr, ppr = 4 * n - 1, 3 * n
        base = self.page * _PAGE + \
            np.arange(rr, dtype=np.int64)[:, None] * (ppr * _PAGE)
        self.pages(0)  # no-op, keeps intent explicit
        self.page += rr * ppr
        rid = self.rid + np.arange(rr, dtype=np.int64)[:, None]
        self.rid += rr
        a = np.zeros((rr, rpr, RECORD_WORDS), dtype=np.int64)
        # sub-share address of party i, as seen by worker w
        def sshare(i: int) -> np.ndarray:
            if i == w:
                return base + (1 + w) * _PAGE
            k = i if i < w else i - 1
            return base + (1 + n + k) * _PAGE
        k = 0
        a[:, k, 0] = _word0(Op.F_MUL_LOCAL, 1, 2, 1)
        a[:, k, _OUT_OFF] = base[:, 0]
        a[:, k, _OUT_OFF + 1] = big
        a[:, k, _IN_OFF] = xa
        a[:, k, _IN_OFF + 1] = big
        a[:, k, _IN_OFF + 2] = ya
        a[:, k, _IN_OFF + 3] = big
        a[:, k, _IMM_OFF] = SH_VEC
        for j in range(n):
            k += 1
            a[:, k, 0] = _word0(Op.F_EVAL, 1, 1, 4)
            a[:, k, _OUT_OFF] = base[:, 0] + (1 + j) * _PAGE
            a[:, k, _OUT_OFF + 1] = big
            a[:, k, _IN_OFF] = base[:, 0]
            a[:, k, _IN_OFF + 1] = big
            a[:, k, _IMM_OFF] = SH_VEC
            a[:, k, _IMM_OFF + 1] = j
            a[:, k, _IMM_OFF + 2] = t
            a[:, k, _IMM_OFF + 3] = rid[:, 0]
        for j in range(n):
            if j == w:
                continue
            k += 1
            a[:, k, 0] = _word0(Op.NET_SEND, 0, 1, 2)
            a[:, k, _IN_OFF] = base[:, 0] + (1 + j) * _PAGE
            a[:, k, _IN_OFF + 1] = big
            a[:, k, _IMM_OFF] = j
            a[:, k, _IMM_OFF + 1] = ROUND_TAG + rid[:, 0]
        for i in range(n):
            if i == w:
                continue
            k += 1
            a[:, k, 0] = _word0(Op.NET_RECV, 1, 0, 2)
            a[:, k, _OUT_OFF] = sshare(i)[:, 0]
            a[:, k, _OUT_OFF + 1] = big
            a[:, k, _IMM_OFF] = i
            a[:, k, _IMM_OFF + 1] = ROUND_TAG + rid[:, 0]
        k += 1
        a[:, k, 0] = _word0(Op.F_MULC, 1, 1, 2)
        a[:, k, _OUT_OFF] = base[:, 0] + 2 * n * _PAGE
        a[:, k, _OUT_OFF + 1] = big
        a[:, k, _IN_OFF] = sshare(0)[:, 0]
        a[:, k, _IN_OFF + 1] = big
        a[:, k, _IMM_OFF] = SH_VEC
        a[:, k, _IMM_OFF + 1] = self.lam[0]
        for q in range(1, n):
            k += 1
            a[:, k, 0] = _word0(Op.F_MULC_ADD, 1, 2, 2)
            a[:, k, _OUT_OFF] = base[:, 0] + (2 * n + q) * _PAGE
            a[:, k, _OUT_OFF + 1] = big
            a[:, k, _IN_OFF] = base[:, 0] + (2 * n + q - 1) * _PAGE
            a[:, k, _IN_OFF + 1] = big
            a[:, k, _IN_OFF + 2] = sshare(q)[:, 0]
            a[:, k, _IN_OFF + 3] = big
            a[:, k, _IMM_OFF] = SH_VEC
            a[:, k, _IMM_OFF + 1] = self.lam[q]
        assert k == rpr - 1
        self.out.append(a.reshape(rr * rpr, RECORD_WORDS))
        return base[:, 0] + (3 * n - 1) * _PAGE

    def reveal(self, addr: int, out_index: int, out_tag: int) -> None:
        n, w = self.n, self.w
        if w != 0:
            r = _rows(1)
            r[0, 0] = _word0(Op.NET_SEND, 0, 1, 2)
            r[0, _IN_OFF] = addr
            r[0, _IN_OFF + 1] = _PAGE
            r[0, _IMM_OFF] = 0
            r[0, _IMM_OFF + 1] = REVEAL_TAG + out_index
            self.out.append(r)
            return
        recv = self.pages(n - 1)
        r = _rows(n - 1)
        r[:, 0] = _word0(Op.NET_RECV, 1, 0, 2)
        r[:, _OUT_OFF] = recv
        r[:, _OUT_OFF + 1] = _PAGE
        r[:, _IMM_OFF] = 1 + np.arange(n - 1, dtype=np.int64)
        r[:, _IMM_OFF + 1] = REVEAL_TAG + out_index
        self.out.append(r)
        acc = self.mulc(addr, self.lam[0])
        for q in range(1, n):
            z = _rows(1)
            nxt = int(self.pages(1)[0])
            z[0, 0] = _word0(Op.F_MULC_ADD, 1, 2, 2)
            z[0, _OUT_OFF] = nxt
            z[0, _OUT_OFF + 1] = _PAGE
            z[0, _IN_OFF] = acc
            z[0, _IN_OFF + 1] = _PAGE
            z[0, _IN_OFF + 2] = recv[q - 1]
            z[0, _IN_OFF + 3] = _PAGE
            z[0, _IMM_OFF] = SH_VEC
            z[0, _IMM_OFF + 1] = self.lam[q]
            self.out.append(z)
            acc = nxt
        o = _rows(1)
        o[0, 0] = _word0(Op.OUTPUT, 0, 1, 2)
        o[0, _IN_OFF] = acc
        o[0, _IN_OFF + 1] = _PAGE
        o[0, _IMM_OFF] = SH_VEC
        o[0, _IMM_OFF + 1] = out_tag
        self.out.append(o)

    def records(self) -> np.ndarray:
        return np.vstack(self.out)


def build_shamir_stats_records(n: int, worker: int,
                               num_workers: int) -> np.ndarray:
    """The FREE-stripped ``shamir_stats`` trace of one worker/party."""
    b = _blocks(n)
    rec = _Rec(worker, num_workers)
    xs = rec.inputs(A_TAGS + np.arange(b, dtype=np.int64))
    s = rec.add_chain(xs)
    inv_b = inverse(b)
    m = rec.mulc(s, inv_b)
    sq = rec.mul_rounds(xs, xs)
    q = rec.add_chain(sq)
    msq = rec.mulc(q, inv_b)
    m2 = int(rec.mul_rounds(np.array([m]), np.array([m]))[0])
    var = rec.sub(msq, m2)
    rec.reveal(s, 0, OUT_TAGS + 0)
    rec.reveal(m, 1, OUT_TAGS + 1)
    rec.reveal(var, 2, OUT_TAGS + 2)
    return rec.records()


def build_shamir_cmp_records(n: int, worker: int,
                             num_workers: int) -> np.ndarray:
    """The FREE-stripped ``shamir_cmp`` trace of one worker/party."""
    b = _blocks(n, lo=2)
    rec = _Rec(worker, num_workers)
    tags = np.empty(2 * b, dtype=np.int64)
    tags[0::2] = A_TAGS + np.arange(b)
    tags[1::2] = B_TAGS + np.arange(b)
    xy = rec.inputs(tags)
    level = rec._bin(Op.F_SUB, xy[0::2], xy[1::2])
    while len(level) > 1:
        nxt = rec.mul_rounds(level[0:-1:2], level[1::2][:len(level) // 2])
        if len(level) % 2:
            nxt = np.concatenate([nxt, level[-1:]])
        level = nxt
    root = int(level[0])
    acc = root
    for bit in bin(P - 1)[3:]:
        acc = int(rec.mul_rounds(np.array([acc]), np.array([acc]))[0])
        if bit == "1":
            acc = int(rec.mul_rounds(np.array([acc]),
                                     np.array([root]))[0])
    rec.reveal(acc, 0, OUT_TAGS + 0)
    return rec.records()


def _write(path, name: str, n: int, worker: int, num_workers: int,
           rec: np.ndarray) -> ProgramFile:
    pages = int(rec[:, _OUT_OFF].max()) // _PAGE + 1
    w = ProgramWriter(path, page_shift=SH_PAGE_SHIFT, protocol="shamir",
                      worker=worker, num_workers=num_workers,
                      vspace_slots=pages << SH_PAGE_SHIFT,
                      meta={"workload": name, "n": n})
    w.append_records(rec)
    return w.close()


def write_shamir_stats_program(path, n: int, worker: int,
                               num_workers: int) -> ProgramFile:
    rec = build_shamir_stats_records(n, worker, num_workers)
    return _write(path, "shamir_stats", n, worker, num_workers, rec)


def write_shamir_cmp_program(path, n: int, worker: int,
                             num_workers: int) -> ProgramFile:
    rec = build_shamir_cmp_records(n, worker, num_workers)
    return _write(path, "shamir_cmp", n, worker, num_workers, rec)
