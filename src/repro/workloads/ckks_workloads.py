"""The five CKKS workloads (§8.1.2): rsum, rstats, rmvmul, n_rmatmul,
t_rmatmul.

Each DSL value is one ciphertext — a vector of N/2 reals computed SIMD-style
over independent problem instances (§8.1.3: "each of our workloads for CKKS
could be applied to [N/2] instances of the problem in a SIMD fashion").
Problem size n = number of elements (rsum/rstats) or matrix side (rmvmul,
*_rmatmul).  Lazy relinearization (mul_norelin + adds + one relin) is used
wherever products are summed — the §7.4 optimization the paper calls
crucial for rstats and the linear-algebra workloads.
"""

from __future__ import annotations

import numpy as np

from ..core.workers import ProgramOptions
from ..protocols.ckks import Batch, CkksParams, Plain
from .base import CKKS_PAGE_SHIFT, Workload, register

X_TAGS = 0
Y_TAGS = 1 << 20
C_TAGS = 1 << 22          # plaintext constants
OUT_TAGS = 1 << 24

PARAMS = CkksParams(n_ring=128, levels=2)   # tests; benches override n_ring


def _params(opts_or_extra) -> CkksParams:
    extra = opts_or_extra.extra if isinstance(opts_or_extra, ProgramOptions) \
        else opts_or_extra
    return extra.get("ckks_params", PARAMS)


def _vals(n: int, seed: int, slots: int) -> np.ndarray:
    """n independent slot-vectors in [-1, 1)."""
    rng = np.random.default_rng(seed)
    return rng.uniform(-1, 1, (n, slots))


def _provider(data_by_base: dict[int, np.ndarray]):
    def provider(tag: int) -> np.ndarray:
        for base, data in data_by_base.items():
            if base <= tag < base + (1 << 20):
                return data[tag - base]
        raise KeyError(tag)
    return provider


# ---------------------------------------------------------------------------
# rsum: sum of n encrypted vectors (no multiplications)
# ---------------------------------------------------------------------------


def _gather_add(acc: Batch, opts: ProgramOptions, p: CkksParams,
                tag0: int) -> Batch | None:
    """Combine per-worker partials on worker 0 (network directives)."""
    from ..core.workers import recv_into, send_value
    if opts.num_workers == 1:
        return acc
    if opts.worker != 0:
        send_value(acc, 0, tag=tag0 + opts.worker)
        return None
    for src in range(1, opts.num_workers):
        other = Batch(p, acc.level, acc.ncomp, acc.scale)
        recv_into(other, src, tag0 + src)
        acc = acc + other
    return acc


def _rsum_build(opts: ProgramOptions) -> None:
    p = _params(opts)
    n = opts.problem_size
    per = n // opts.num_workers
    base = opts.worker * per
    cts = [Batch(p).mark_input(X_TAGS + base + i) for i in range(per)]
    acc = cts[0] + cts[1]
    for c in cts[2:]:
        acc = acc + c
    acc = _gather_add(acc, opts, p, 1 << 16)
    if acc is not None:
        acc.mark_output(OUT_TAGS)


def _rsum_inputs(n: int, worker: int, p: int):
    return _provider({X_TAGS: _vals(n, 7000 + n, PARAMS.slots)})


def _rsum_oracle(n: int) -> dict[int, np.ndarray]:
    return {OUT_TAGS: _vals(n, 7000 + n, PARAMS.slots).sum(axis=0)}


register(Workload("rsum", "ckks", _rsum_build, _rsum_inputs, _rsum_oracle,
                  page_shift=CKKS_PAGE_SHIFT, default_n=64))


# ---------------------------------------------------------------------------
# rstats: mean and variance (depth 2, lazy relin)
# ---------------------------------------------------------------------------


def _rstats_build(opts: ProgramOptions) -> None:
    p = _params(opts)
    n = opts.problem_size
    per = n // opts.num_workers
    base = opts.worker * per
    inv_n = Plain(p).mark_input(C_TAGS)          # encodes 1/n
    cts = [Batch(p).mark_input(X_TAGS + base + i) for i in range(per)]
    s = cts[0] + cts[1]
    for c in cts[2:]:
        s = s + c
    sq = cts[0].mul_norelin(cts[0])
    for c in cts[1:]:
        sq = sq + c.mul_norelin(c)
    s = _gather_add(s, opts, p, 1 << 16)
    sq = _gather_add(sq, opts, p, 1 << 17)
    if s is None:
        return
    sumsq = sq.relin()                            # level 1
    mean = s.mul_plain(inv_n)                     # level 1
    ex2 = sumsq.mul_plain(inv_n)                  # level 0
    mean2 = mean * mean                           # level 0
    var = ex2 - mean2
    mean.mark_output(OUT_TAGS)
    var.mark_output(OUT_TAGS + 1)


def _rstats_inputs(n: int, worker: int, p: int):
    xs = _vals(n, 7100 + n, PARAMS.slots)
    const = np.full(PARAMS.slots, 1.0 / n)
    return _provider({X_TAGS: xs, C_TAGS: const[None, :]})


def _rstats_oracle(n: int) -> dict[int, np.ndarray]:
    xs = _vals(n, 7100 + n, PARAMS.slots)
    return {OUT_TAGS: xs.mean(axis=0),
            OUT_TAGS + 1: xs.var(axis=0)}


register(Workload("rstats", "ckks", _rstats_build, _rstats_inputs,
                  _rstats_oracle, page_shift=CKKS_PAGE_SHIFT, default_n=64))


# ---------------------------------------------------------------------------
# rmvmul: encrypted matrix-vector multiply (lazy relin per row)
# ---------------------------------------------------------------------------


def _rmv_tag(i: int, j: int, n: int) -> int:
    return X_TAGS + i * n + j


def _rmvmul_build(opts: ProgramOptions) -> None:
    p = _params(opts)
    n = opts.problem_size
    rows = n // opts.num_workers
    r0 = opts.worker * rows
    vec = [Batch(p).mark_input(Y_TAGS + j) for j in range(n)]
    for i in range(r0, r0 + rows):
        row = [Batch(p).mark_input(_rmv_tag(i, j, n)) for j in range(n)]
        acc = row[0].mul_norelin(vec[0])
        for j in range(1, n):
            acc = acc + row[j].mul_norelin(vec[j])
        acc.relin().mark_output(OUT_TAGS + i)


def _rmvmul_data(n: int):
    return (_vals(n * n, 7200 + n, PARAMS.slots),
            _vals(n, 7300 + n, PARAMS.slots))


def _rmvmul_inputs(n: int, worker: int, p: int):
    M, v = _rmvmul_data(n)
    return _provider({X_TAGS: M, Y_TAGS: v})


def _rmvmul_oracle(n: int) -> dict[int, np.ndarray]:
    M, v = _rmvmul_data(n)
    out = {}
    for i in range(n):
        acc = np.zeros(PARAMS.slots)
        for j in range(n):
            acc += M[i * n + j] * v[j]
        out[OUT_TAGS + i] = acc
    return out


register(Workload("rmvmul", "ckks", _rmvmul_build, _rmvmul_inputs,
                  _rmvmul_oracle, page_shift=CKKS_PAGE_SHIFT, default_n=8))


# ---------------------------------------------------------------------------
# n_rmatmul / t_rmatmul: naive vs tiled matrix-matrix multiply
# ---------------------------------------------------------------------------


def _matmul_data(n: int):
    return (_vals(n * n, 7400 + n, PARAMS.slots),
            _vals(n * n, 7500 + n, PARAMS.slots))


def _matmul_inputs(n: int, worker: int, p: int):
    A, B = _matmul_data(n)
    return _provider({X_TAGS: A, Y_TAGS: B})


def _matmul_oracle(n: int) -> dict[int, np.ndarray]:
    A, B = _matmul_data(n)
    out = {}
    for i in range(n):
        for k in range(n):
            acc = np.zeros(PARAMS.slots)
            for j in range(n):
                acc += A[i * n + j] * B[j * n + k]
            out[OUT_TAGS + i * n + k] = acc
    return out


def _n_rmatmul_build(opts: ProgramOptions) -> None:
    """Naive i-j-k loop: the whole A row band, B, and C accumulators are
    repeatedly rescanned — the memory-hostile ordering."""
    p = _params(opts)
    n = opts.problem_size
    rows = n // opts.num_workers
    r0 = opts.worker * rows
    A = {(i, j): Batch(p).mark_input(X_TAGS + i * n + j)
         for i in range(r0, r0 + rows) for j in range(n)}
    B = {(j, k): Batch(p).mark_input(Y_TAGS + j * n + k)
         for j in range(n) for k in range(n)}
    C: dict[tuple[int, int], Batch] = {}
    for i in range(r0, r0 + rows):
        for j in range(n):
            for k in range(n):
                t = A[(i, j)].mul_norelin(B[(j, k)])
                C[(i, k)] = t if j == 0 else C[(i, k)] + t
    for i in range(r0, r0 + rows):
        for k in range(n):
            C[(i, k)].relin().mark_output(OUT_TAGS + i * n + k)


def _t_rmatmul_build(opts: ProgramOptions) -> None:
    """Tiled i-k-j loop with T x T tiles: each B tile is reused across a
    whole A row-tile before moving on (the memory-friendly ordering)."""
    p = _params(opts)
    n = opts.problem_size
    T = min(4, n)
    rows = n // opts.num_workers
    r0 = opts.worker * rows
    A = {(i, j): Batch(p).mark_input(X_TAGS + i * n + j)
         for i in range(r0, r0 + rows) for j in range(n)}
    B = {(j, k): Batch(p).mark_input(Y_TAGS + j * n + k)
         for j in range(n) for k in range(n)}
    C: dict[tuple[int, int], Batch] = {}
    for i0 in range(r0, r0 + rows, T):
        for k0 in range(0, n, T):
            for j0 in range(0, n, T):
                for i in range(i0, min(i0 + T, r0 + rows)):
                    for k in range(k0, min(k0 + T, n)):
                        for j in range(j0, min(j0 + T, n)):
                            t = A[(i, j)].mul_norelin(B[(j, k)])
                            C[(i, k)] = t if j == 0 else C[(i, k)] + t
            for i in range(i0, min(i0 + T, r0 + rows)):
                for k in range(k0, min(k0 + T, n)):
                    C.pop((i, k)).relin().mark_output(OUT_TAGS + i * n + k)


register(Workload("n_rmatmul", "ckks", _n_rmatmul_build, _matmul_inputs,
                  _matmul_oracle, page_shift=CKKS_PAGE_SHIFT, default_n=4))
register(Workload("t_rmatmul", "ckks", _t_rmatmul_build, _matmul_inputs,
                  _matmul_oracle, page_shift=CKKS_PAGE_SHIFT, default_n=4))
