"""Workload harness: the ten §8.1 kernels + §8.8 applications.

Each workload packages (1) a DSL program parameterized by ProgramOptions,
(2) deterministic synthetic inputs, (3) a numpy oracle for its outputs, and
(4) protocol/page-size defaults matching the paper (GC: 64 KiB pages = 4096
wires; CKKS: word-addressed pages sized a few ciphertexts).

All workloads follow the paper's three-phase discipline (§8.1.3): inputs are
materialized in memory first, then the computation runs, then outputs are
written — deliberately NOT streaming, so that memory pressure is real.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from ..core.bytecode import Program
from ..core.workers import ProgramOptions, trace_workers

GC_PAGE_SHIFT = 12    # 4096 wires * 16 B = 64 KiB, the paper's GC page size
CKKS_PAGE_SHIFT = 14  # 16384 words = 128 KiB pages (scaled with our N)


@dataclasses.dataclass
class Workload:
    name: str
    protocol: str                      # 'gc' | 'ckks'
    build: Callable[[ProgramOptions], None]
    inputs: Callable[[int, int, int], Callable[[int], np.ndarray]]
    # (problem_size, worker, num_workers) -> provider(tag)
    oracle: Callable[[int], dict[int, np.ndarray]]
    page_shift: int = GC_PAGE_SHIFT
    default_n: int = 256
    params: dict = dataclasses.field(default_factory=dict)

    def trace(self, n: int | None = None, num_workers: int = 1,
              **extra) -> list[Program]:
        n = n or self.default_n
        return trace_workers(self.build, protocol=self.protocol,
                             page_shift=self.page_shift,
                             num_workers=num_workers, problem_size=n,
                             extra={**self.params, **extra})


REGISTRY: dict[str, Workload] = {}


def register(w: Workload) -> Workload:
    REGISTRY[w.name] = w
    return w


def get(name: str) -> Workload:
    import repro.workloads.gc_workloads  # noqa: F401
    import repro.workloads.ckks_workloads  # noqa: F401
    import repro.workloads.apps  # noqa: F401
    import repro.workloads.agg_workload  # noqa: F401
    import repro.workloads.shamir_workloads  # noqa: F401
    return REGISTRY[name]


def all_names() -> list[str]:
    get("merge")  # force registration
    return sorted(REGISTRY)
