"""Run workloads end-to-end: plaintext oracle check, real two-party GC /
CKKS execution, bounded-memory execution — the correctness half of §8's
methodology (the timing half lives in benchmarks/)."""

from __future__ import annotations

import threading

import numpy as np

from ..core.bytecode import Program
from ..core.engine import Channels, Engine
from ..core.planner import PlanConfig, plan
from ..protocols.ckks import CkksDriver, CkksParams
from ..protocols.garbled.driver import (EvaluatorDriver, GarblerDriver,
                                        PlaintextDriver)
from ..protocols.garbled.gates import PartyChannel
from .base import Workload
from .ckks_workloads import PARAMS as CKKS_PARAMS


def plan_programs(progs: list[Program], cfg: PlanConfig | None):
    if cfg is None:
        return progs, []
    out, reps = [], []
    for p in progs:
        mp, rep = plan(p, cfg)
        out.append(mp)
        reps.append(rep)
    return out, reps


def run_gc_plaintext(w: Workload, n: int, num_workers: int = 1,
                     cfg: PlanConfig | None = None,
                     use_memmap: bool = False) -> dict[int, np.ndarray]:
    progs = w.trace(n, num_workers)
    progs, _ = plan_programs(progs, cfg)
    channels = Channels(num_workers)
    outputs: dict[int, np.ndarray] = {}
    drivers = [PlaintextDriver(w.inputs(n, i, num_workers))
               for i in range(num_workers)]
    errs: list[Exception] = []

    def _run(i: int):
        try:
            Engine(progs[i], drivers[i], channels=channels,
                   use_memmap=use_memmap).run()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=_run, args=(i,), daemon=True)
          for i in range(num_workers)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    if errs:
        raise errs[0]
    for d in drivers:
        outputs.update(d.outputs)
    return outputs


def run_gc_real(w: Workload, n: int, num_workers: int = 1,
                cfg: PlanConfig | None = None,
                use_memmap: bool = False) -> dict[int, np.ndarray]:
    """Both parties, all workers: 2p engines, one PartyChannel per worker
    pair (one-to-one inter-party topology, Fig. 3)."""
    progs = w.trace(n, num_workers)
    progs, _ = plan_programs(progs, cfg)
    ch_g = Channels(num_workers)
    ch_e = Channels(num_workers)
    pchans = [PartyChannel() for _ in range(num_workers)]
    g_drivers = [GarblerDriver(pchans[i], w.inputs(n, i, num_workers),
                               seed=7)
                 for i in range(num_workers)]
    e_drivers = [EvaluatorDriver(pchans[i], w.inputs(n, i, num_workers))
                 for i in range(num_workers)]
    errs: list[Exception] = []

    def _run(drv, prog, chans):
        try:
            Engine(prog, drv, channels=chans, use_memmap=use_memmap).run()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = []
    for i in range(num_workers):
        ts.append(threading.Thread(target=_run,
                                   args=(g_drivers[i], progs[i], ch_g),
                                   daemon=True))
        ts.append(threading.Thread(target=_run,
                                   args=(e_drivers[i], progs[i], ch_e),
                                   daemon=True))
    [t.start() for t in ts]
    [t.join() for t in ts]
    if errs:
        raise errs[0]
    outputs: dict[int, np.ndarray] = {}
    for d in e_drivers:
        outputs.update(d.outputs)
    return outputs


def run_ckks(w: Workload, n: int, num_workers: int = 1,
             cfg: PlanConfig | None = None, use_memmap: bool = False,
             params: CkksParams | None = None) -> dict[int, np.ndarray]:
    params = params or w.params.get("ckks_params", CKKS_PARAMS)
    progs = w.trace(n, num_workers)
    progs, _ = plan_programs(progs, cfg)
    channels = Channels(num_workers)
    drivers = [CkksDriver(params, w.inputs(n, i, num_workers), seed=0xCEC5)
               for i in range(num_workers)]
    errs: list[Exception] = []

    def _run(i: int):
        try:
            Engine(progs[i], drivers[i], channels=channels,
                   use_memmap=use_memmap).run()
        except Exception as e:  # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=_run, args=(i,), daemon=True)
          for i in range(num_workers)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    if errs:
        raise errs[0]
    outputs: dict[int, np.ndarray] = {}
    for d in drivers:
        outputs.update(d.outputs)
    return outputs


def run(w: Workload, n: int, real: bool = False, **kw) -> dict[int, np.ndarray]:
    if w.protocol == "gc":
        return (run_gc_real if real else run_gc_plaintext)(w, n, **kw)
    return run_ckks(w, n, **kw)


def check_against_oracle(w: Workload, n: int, outputs: dict[int, np.ndarray],
                         atol: float = 2e-2) -> None:
    exp = w.oracle(n)
    missing = set(exp) - set(outputs)
    assert not missing, f"{w.name}: missing outputs {sorted(missing)[:5]}..."
    for tag, e in exp.items():
        got = outputs[tag]
        if w.protocol == "gc":
            assert np.array_equal(got, e), \
                f"{w.name} tag {tag}: {got[:4]} != {e[:4]}"
        else:
            err = np.max(np.abs(np.asarray(got) - e))
            assert err < atol, f"{w.name} tag {tag}: err {err}"
