"""Run workloads end-to-end: plaintext oracle check, real two-party GC /
CKKS execution, bounded-memory execution — the correctness half of §8's
methodology (the timing half lives in repro.scenarios / benchmarks/).

These are thin compatibility wrappers over :class:`repro.api.Session`;
the worker-orchestration core (thread spawn, error collection) lives in
``repro.core.workers.run_engines`` and nowhere else.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..api import JobSpec, Session, check_outputs
from ..core.planner import PlanConfig
from .base import Workload


def _spec(w: Workload, n: int, num_workers: int, cfg: PlanConfig | None,
          use_memmap: bool, driver: str) -> JobSpec:
    kw = dict(workload=w.name, n=n, num_workers=num_workers, driver=driver,
              storage="memmap" if use_memmap else "ram")
    if cfg is None:
        kw["plan_mode"] = "unbounded"
    else:
        kw.update(memory_budget=cfg.num_frames, lookahead=cfg.lookahead,
                  prefetch_pages=cfg.prefetch_pages, policy=cfg.policy,
                  swap_bypass=cfg.swap_bypass)
    return JobSpec(**kw)


def run_gc_plaintext(w: Workload, n: int, num_workers: int = 1,
                     cfg: PlanConfig | None = None,
                     use_memmap: bool = False) -> dict[int, np.ndarray]:
    with Session(_spec(w, n, num_workers, cfg, use_memmap,
                       "gc-plaintext")) as s:
        return s.execute()


def run_gc_real(w: Workload, n: int, num_workers: int = 1,
                cfg: PlanConfig | None = None,
                use_memmap: bool = False) -> dict[int, np.ndarray]:
    """Both parties, all workers: 2p engines, one PartyChannel per worker
    pair (one-to-one inter-party topology, Fig. 3)."""
    with Session(_spec(w, n, num_workers, cfg, use_memmap,
                       "gc-2party")) as s:
        return s.execute()


def run_ckks(w: Workload, n: int, num_workers: int = 1,
             cfg: PlanConfig | None = None, use_memmap: bool = False,
             params=None) -> dict[int, np.ndarray]:
    if params is not None:
        # full CkksParams override (all fields, not just ring/levels):
        # make it the workload's base params for this run
        w = dataclasses.replace(w, params={**w.params,
                                           "ckks_params": params})
    with Session(_spec(w, n, num_workers, cfg, use_memmap, "ckks"),
                 workload=w) as s:
        return s.execute()


def run(w: Workload, n: int, real: bool = False, **kw) -> dict[int, np.ndarray]:
    if w.protocol == "gc":
        return (run_gc_real if real else run_gc_plaintext)(w, n, **kw)
    return run_ckks(w, n, **kw)


def check_against_oracle(w: Workload, n: int, outputs: dict[int, np.ndarray],
                         atol: float = 2e-2) -> None:
    check_outputs(w, n, outputs, atol=atol)
