from .base import (CKKS_PAGE_SHIFT, GC_PAGE_SHIFT, REGISTRY, Workload,
                   all_names, get, register)

__all__ = ["CKKS_PAGE_SHIFT", "GC_PAGE_SHIFT", "REGISTRY", "Workload",
           "all_names", "get", "register"]
