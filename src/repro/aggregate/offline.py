"""Secure-aggregation offline phase: specs, key material, round plans.

The production shape of MAGE's thesis — SC programs are oblivious, so
their resource schedule is computable ahead of time — is federated
secure aggregation: every round ingests the same number of shares, of
the same size, under tags known in advance.  This module is everything
that can be derived *before* any client connects:

* :class:`AggSpec` — the job description.  ``plan_key()`` hashes the
  plan-relevant subset (mirroring ``JobSpec.plan_hash``), so round
  plans are cacheable across rounds, runs and daemon restarts through
  ``ArtifactCache``'s ``agg`` kind.
* additive secret sharing mod 2**64: client ``c``'s round-``r`` vector
  splits into one share per compute server.  All but the last share are
  pseudorandom functions of ``(seed, client, server, round)`` — exactly
  the per-client mask/key material a real deployment would provision
  offline — and the last share is the vector minus the others.  Because
  every share is a pure function of ``(client, server, round)``, the
  revealed aggregate over any surviving-client subset is bitwise
  independent of *which run* produced it: a straggler-degraded round
  equals a straggler-free round over the same survivors.
* :func:`build_round_plan` — the per-round ingestion schedule (client →
  gateway assignment, tag layout, O(clients) admission estimates).  The
  online phase never recomputes this; it loads it (``load_round_plan``)
  from the artifact cache, where hot rounds hit with zero re-plans.

Tag layout: data/control tags live far above the DSL's small
non-negative tag space and far below the transport's deeply negative
barrier ranges, partitioned per purpose so a round's client shares,
manifests, survivor votes and partial sums can never collide.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

__all__ = [
    "AggSpec", "RoundPlan", "DEFAULT_SEED", "FRAME_BYTES",
    "build_round_plan", "load_round_plan", "client_vector",
    "client_shares", "expected_sum", "data_tag", "manifest_tag",
    "survivor_tag", "partial_tag",
]

DEFAULT_SEED = 7
#: admission accounting unit: one 64 KiB frame (the paper's GC page size)
FRAME_BYTES = 64 << 10

#: reserved control/data tag ranges (disjoint by construction)
TAG_MANIFEST_BASE = 1 << 32
TAG_DATA_BASE = 1 << 33
TAG_SURVIVOR_BASE = 1 << 34
TAG_PARTIAL_BASE = 1 << 35

#: domain-separation constants for the PRG seed tuples
_DOM_DATA = 0xDA7A
_DOM_MASK = 0xA11CE


@dataclasses.dataclass(frozen=True)
class AggSpec:
    """One secure-aggregation job: N input-only clients stream additive
    shares to ``servers`` compute endpoints via ``gateways`` transport
    endpoints (thousands of logical clients multiplexed over a few
    fabric ranks — the fan-in axis is the *tag* space, not the socket
    count)."""

    clients: int
    vec_len: int = 64
    rounds: int = 1
    servers: int = 2
    gateways: int = 2
    seed: int = DEFAULT_SEED
    # online-phase knobs (not plan-hashed: they shape resource use, never
    # the aggregate)
    max_inflight_msgs: int = 0
    max_inflight_bytes: int = 1 << 20
    round_timeout_s: float = 30.0
    frame_pool: int = 1 << 16

    #: fields the round plan is a pure function of
    PLAN_FIELDS = ("clients", "vec_len", "rounds", "servers", "gateways",
                   "seed")

    def __post_init__(self):
        if self.clients <= 0 or self.vec_len <= 0 or self.rounds <= 0:
            raise ValueError("clients, vec_len and rounds must be positive")
        if self.servers < 1 or self.gateways < 1:
            raise ValueError("need at least one server and one gateway")

    @property
    def num_endpoints(self) -> int:
        """Fabric rank space: servers are ranks [0, S), gateways
        [S, S+G)."""
        return self.servers + self.gateways

    def gateway_rank(self, g: int) -> int:
        return self.servers + g

    def gateway_of(self, client: int) -> int:
        return client % self.gateways

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "AggSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def plan_key(self) -> str:
        doc = {k: getattr(self, k) for k in self.PLAN_FIELDS}
        blob = json.dumps(doc, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]


# -- tag layout --------------------------------------------------------------


def data_tag(spec: AggSpec, rnd: int, client: int) -> int:
    """Per-(round, client) share tag: every client on a shared
    gateway→server link is its own reorder-buffer lane."""
    return TAG_DATA_BASE + rnd * spec.clients + client


def manifest_tag(rnd: int) -> int:
    return TAG_MANIFEST_BASE + rnd


def survivor_tag(rnd: int) -> int:
    return TAG_SURVIVOR_BASE + rnd


def partial_tag(rnd: int) -> int:
    return TAG_PARTIAL_BASE + rnd


# -- key material / shares ---------------------------------------------------


def client_vector(seed: int, client: int, rnd: int,
                  vec_len: int) -> np.ndarray:
    """Client ``client``'s secret round-``rnd`` input (deterministic
    synthetic data, uint64)."""
    rng = np.random.default_rng((seed, _DOM_DATA, rnd, client))
    return rng.integers(0, 1 << 64, vec_len, dtype=np.uint64)


def _mask(seed: int, client: int, server: int, rnd: int,
          vec_len: int) -> np.ndarray:
    rng = np.random.default_rng((seed, _DOM_MASK, rnd, client, server))
    return rng.integers(0, 1 << 64, vec_len, dtype=np.uint64)


def client_shares(spec: AggSpec, client: int, rnd: int) -> list[np.ndarray]:
    """Additive shares of ``client_vector`` mod 2**64, one per server.

    Shares 0..S-2 are the offline-provisioned masks; the last share is
    the vector minus their sum (uint64 wraparound), so the shares sum to
    the vector and any S-1 of them are uniformly random."""
    x = client_vector(spec.seed, client, rnd, spec.vec_len)
    shares = [_mask(spec.seed, client, k, rnd, spec.vec_len)
              for k in range(spec.servers - 1)]
    used = np.zeros(spec.vec_len, dtype=np.uint64)
    for s in shares:
        used += s                     # uint64 wraparound is the group op
    shares.append(x - used)
    return shares


def expected_sum(spec: AggSpec, rnd: int,
                 survivors=None) -> np.ndarray:
    """The reference aggregate: sum of the surviving clients' vectors
    mod 2**64 (the single-process oracle the fleet must match bitwise)."""
    ids = range(spec.clients) if survivors is None else sorted(survivors)
    out = np.zeros(spec.vec_len, dtype=np.uint64)
    for c in ids:
        out += client_vector(spec.seed, c, rnd, spec.vec_len)
    return out


# -- round plan --------------------------------------------------------------


@dataclasses.dataclass
class RoundPlan:
    """The oblivious per-round ingestion schedule, derived offline.

    ``gateway_clients[g]`` is gateway g's client list (its send order);
    ``frames``/``mem_bytes`` are the O(clients) admission estimates one
    server pins per round (the gathered share matrix); ``share_bytes``
    is one client message's payload size, from which the server derives
    its per-link backpressure depth."""

    key: str
    clients: int
    gateway_clients: list[list[int]]
    frames: int
    mem_bytes: int
    share_bytes: int

    def to_dict(self) -> dict:
        return {"version": 1, **dataclasses.asdict(self)}

    @classmethod
    def from_dict(cls, d: dict) -> "RoundPlan":
        if d.get("version") != 1:
            raise ValueError(f"unknown round-plan version {d.get('version')}")
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def build_round_plan(spec: AggSpec) -> RoundPlan:
    """Derive the round plan from the spec (the re-plan path; the online
    phase should hit the cache instead — see :func:`load_round_plan`)."""
    gw: list[list[int]] = [[] for _ in range(spec.gateways)]
    for c in range(spec.clients):
        gw[spec.gateway_of(c)].append(c)
    share_bytes = spec.vec_len * 8
    mem_bytes = spec.clients * share_bytes
    frames = max(1, -(-mem_bytes // FRAME_BYTES))
    return RoundPlan(key=spec.plan_key(), clients=spec.clients,
                     gateway_clients=gw, frames=frames,
                     mem_bytes=mem_bytes, share_bytes=share_bytes)


def load_round_plan(cache, spec: AggSpec) -> tuple[RoundPlan, str]:
    """Round plan via the artifact cache: ``(plan, "hit"|"miss"|"none")``.

    ``cache=None`` (no cache configured) builds in memory and reports
    ``"none"``.  On a miss the freshly built plan is published, so every
    hot round — and every later run with the same plan-relevant spec —
    reuses it with zero re-plans (verified by ``CacheStats.agg_*``)."""
    if cache is None:
        return build_round_plan(spec), "none"
    doc = cache.get_agg(spec)
    if doc is not None:
        return RoundPlan.from_dict(doc), "hit"
    plan = build_round_plan(spec)
    cache.put_agg(spec, plan.to_dict())
    return plan, "miss"
