"""Many-client secure aggregation over the transport fabric.

Input-only clients (thousands, multiplexed over a few gateway
endpoints) stream additive shares to a small compute fleet; the
per-round schedule is derived offline and cached.  See
docs/AGGREGATE.md for the architecture and ``python -m repro agg``
for the CLI.
"""

from .offline import (AggSpec, RoundPlan, build_round_plan, client_shares,
                      client_vector, expected_sum, load_round_plan)
from .run import AggResult, run_aggregation, verify_aggregates

__all__ = [
    "AggSpec", "RoundPlan", "AggResult", "build_round_plan",
    "load_round_plan", "client_vector", "client_shares", "expected_sum",
    "run_aggregation", "verify_aggregates",
]
