"""Secure-aggregation online phase, compute side: one fleet server.

Per round, a server:

1. loads the round plan (rank 0 through the artifact cache — hot rounds
   are zero re-plans, counter-verified; other ranks reuse the plan they
   were handed offline),
2. reserves the round's O(clients) footprint with the
   :class:`AdmissionController` (``plan.frames`` frames, the gathered
   share matrix in bytes) — the same admission path serve jobs use,
3. **batch-ingests**: receives every announced client share directly
   into one pre-allocated ``[clients, vec_len]`` uint64 matrix (the
   transport's ``out=`` fast path), then reduces it with ONE vectorized
   NumPy sum — per-message Python work is a dict lookup and a memcpy,
   the arithmetic is a single ``np.add.reduce``,
4. agrees on survivors: servers exchange received-client bitmaps and
   intersect, so every server reduces exactly the same subset even if a
   straggler's share reached only some of the fleet,
5. reveals: non-zero ranks ship their partial sum to rank 0, which adds
   them — additive shares make the reveal a plain uint64 sum.

Straggler handling is *reported, never silently wrong*: a gateway whose
manifest misses the round timeout drops all its clients for that round;
a client missing from the intersected bitmap drops from the reduction;
the round result names its surviving subset and is bitwise equal to a
straggler-free run over the same survivors (shares are pure functions
of (client, server, round) — see ``offline.py``).
"""

from __future__ import annotations

import numpy as np

from ..core.transport import TransportError
from .client import LatencyBook
from .offline import (AggSpec, RoundPlan, data_tag, manifest_tag,
                      partial_tag, survivor_tag)

__all__ = ["RoundResult", "run_server"]


class RoundResult:
    """One round as seen by rank 0: the revealed aggregate, who made it
    in, and whether the round degraded below the announced population."""

    def __init__(self, rnd: int, total: np.ndarray | None,
                 survivors: list[int], expected_clients: int,
                 plan_event: str):
        self.rnd = rnd
        self.total = total
        self.survivors = survivors
        self.expected_clients = expected_clients
        self.plan_event = plan_event

    @property
    def degraded(self) -> bool:
        return len(self.survivors) < self.expected_clients


def _ingest_round(transport, spec: AggSpec, plan: RoundPlan, k: int,
                  rnd: int, buf: np.ndarray, latency: LatencyBook | None
                  ) -> np.ndarray:
    """Receive the round's manifests + shares into ``buf``; return the
    received-client boolean mask.  A gateway that misses the round
    timeout loses its whole client block for this round."""
    got = np.zeros(spec.clients, dtype=bool)
    for g in range(spec.gateways):
        gw = spec.gateway_rank(g)
        try:
            man = transport.recv(gw, k, manifest_tag(rnd),
                                 timeout=spec.round_timeout_s)
        except TransportError:
            continue                      # dead/late gateway: block dropped
        for c in map(int, man):
            try:
                transport.recv(gw, k, data_tag(spec, rnd, c), out=buf[c],
                               timeout=spec.round_timeout_s)
            except TransportError:
                continue                  # announced but never arrived
            got[c] = True
            if latency is not None and k == 0:
                latency.ingested(rnd, c)
    return got


def _agree_survivors(transport, spec: AggSpec, k: int, rnd: int,
                     got: np.ndarray) -> np.ndarray:
    """All-to-all bitmap exchange; the fleet reduces the intersection,
    so a share that reached only part of the fleet is dropped everywhere
    (otherwise the shares would not cancel)."""
    agreed = got
    if spec.servers > 1:
        mine = np.packbits(got)
        for j in range(spec.servers):
            if j != k:
                transport.send(k, j, survivor_tag(rnd), mine)
        for j in range(spec.servers):
            if j != k:
                theirs = transport.recv(j, k, survivor_tag(rnd),
                                        timeout=spec.round_timeout_s)
                agreed = agreed & np.unpackbits(
                    theirs, count=spec.clients).astype(bool)
    return agreed


def run_server(transport, spec: AggSpec, k: int, admission,
               plan_loader, latency: LatencyBook | None = None) -> dict:
    """Run server rank ``k`` for all rounds.

    ``plan_loader()`` is called once per round and returns
    ``(RoundPlan, event)`` — rank 0 wires it to the artifact cache,
    peers to the offline-distributed plan.  Returns the per-rank report;
    rank 0's includes the revealed aggregates."""
    rounds: list[RoundResult] = []
    plan_events: list[str] = []
    for rnd in range(spec.rounds):
        plan, event = plan_loader()
        plan_events.append(event)
        with admission.admit(plan.frames, plan.mem_bytes,
                             timeout=spec.round_timeout_s):
            buf = np.zeros((spec.clients, spec.vec_len), dtype=np.uint64)
            got = _ingest_round(transport, spec, plan, k, rnd, buf, latency)
            agreed = _agree_survivors(transport, spec, k, rnd, got)
            # the round's entire arithmetic: one vectorized reduction
            partial = np.add.reduce(buf[agreed], axis=0,
                                    dtype=np.uint64, initial=np.uint64(0))
        survivors = [int(c) for c in np.flatnonzero(agreed)]
        if k != 0:
            transport.send(k, 0, partial_tag(rnd), partial, copy=False)
            rounds.append(RoundResult(rnd, None, survivors, spec.clients,
                                      event))
            continue
        total = partial.copy()
        for j in range(1, spec.servers):
            total += transport.recv(j, 0, partial_tag(rnd),
                                    timeout=spec.round_timeout_s)
        rounds.append(RoundResult(rnd, total, survivors, spec.clients,
                                  event))
    return {
        "rank": k,
        "rounds": rounds,
        "plan_events": plan_events,
    }
