"""Secure-aggregation online phase, input side: the client swarm.

Thousands of *logical* clients are multiplexed over a few gateway
endpoints of the transport fabric (ranks ``[S, S+G)``): each client's
per-round share to server ``k`` is one tagged message on the shared
``gateway → server`` link, under the per-(round, client) tag from the
offline plan.  Fan-in therefore scales in the TAG space — the fabric's
per-tag reorder buffers — not in sockets or threads, which is what lets
one process simulate 10^3..10^4 clients against a 2-4 server fleet.

Flow control is the transport's own reorder-buffer depth knob: the
server bounds each gateway link's pending bytes, so a gateway running
ahead of the reduction blocks in ``send`` instead of materializing the
round in server memory (verified by ``reorder_stats`` high-water marks).

Straggler model: clients listed in ``drop`` for a round simply never
send — the gateway's per-round *manifest* (the client list it is about
to stream) tells each server exactly what to expect, so a missing
client costs the server a manifest diff, not a receive timeout.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from .offline import AggSpec, RoundPlan, client_shares, data_tag, manifest_tag

__all__ = ["LatencyBook", "run_gateway"]


class LatencyBook:
    """Per-client share latency: send stamp at the gateway, ingest stamp
    at the server (same process only — wall-clock stamps do not cross
    the wire).  ``samples`` are seconds from a client emitting its
    shares to server 0 having its row gathered."""

    def __init__(self):
        self._lock = threading.Lock()
        self._sent: dict[tuple[int, int], float] = {}
        self.samples: list[float] = []

    def sent(self, rnd: int, client: int) -> None:
        with self._lock:
            self._sent[(rnd, client)] = time.monotonic()

    def ingested(self, rnd: int, client: int) -> None:
        now = time.monotonic()
        with self._lock:
            t0 = self._sent.pop((rnd, client), None)
            if t0 is not None:
                self.samples.append(now - t0)

    def percentiles_ms(self, qs=(50, 90, 99)) -> dict[str, float]:
        if not self.samples:
            return {}
        arr = np.asarray(self.samples) * 1e3
        return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def run_gateway(transport, spec: AggSpec, plan: RoundPlan, g: int,
                drop: frozenset = frozenset(),
                latency: LatencyBook | None = None) -> dict:
    """Stream every round's shares for gateway ``g``'s client block.

    Per round: announce the surviving client list to every server (the
    manifest), then emit each surviving client's shares — one message
    per (client, server).  Returns per-gateway counters."""
    rank = spec.gateway_rank(g)
    mine = plan.gateway_clients[g]
    sent_msgs = 0
    for rnd in range(spec.rounds):
        alive = [c for c in mine if (rnd, c) not in drop]
        man = np.asarray(alive, dtype=np.uint64)
        for k in range(spec.servers):
            transport.send(rank, k, manifest_tag(rnd), man)
        for c in alive:
            if latency is not None:
                latency.sent(rnd, c)
            shares = client_shares(spec, c, rnd)
            for k in range(spec.servers):
                # freshly derived arrays, never touched again: skip the
                # defensive copy on in-process backends
                transport.send(rank, k, data_tag(spec, rnd, c), shares[k],
                               copy=False)
            sent_msgs += spec.servers
    return {"gateway": g, "clients": len(mine), "sent_msgs": sent_msgs}
