"""Secure-aggregation orchestrator: place endpoints, run the rounds,
collect the evidence.

``run_aggregation`` is the one entry point behind the ``python -m repro
agg`` CLI and ``benchmarks/agg_bench.py``.  It builds the fabric
(servers are ranks ``[0, S)``, gateways ``[S, S+G)``), applies the
per-link backpressure depth from the spec, runs every *hosted* endpoint
(all of them in-process, or exactly one under ``--rank`` for
multi-process runs), and returns an :class:`AggResult` whose ``to_doc``
is the CLI's JSON envelope:

* revealed per-round aggregates + the surviving-client subsets (the
  bitwise-identity acceptance surface),
* per-link byte/message accounting and reorder-buffer HIGH-WATER marks
  (the counters that *prove* in-flight bytes stayed under the knobs),
* admission-controller status, plan-cache events and cache counters
  (the zero-re-plan evidence), and client→ingest latency percentiles.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from ..core.transport import Fabric, FabricSpec, build_fabric
from ..serve_daemon.admission import AdmissionController
from .client import LatencyBook, run_gateway
from .offline import AggSpec, build_round_plan, expected_sum, load_round_plan
from .server import run_server

__all__ = ["AggResult", "run_aggregation", "verify_aggregates"]


@dataclasses.dataclass
class AggResult:
    """Everything one process learned from an aggregation run.  On a
    distributed non-zero rank, ``rounds`` is empty (only rank 0
    reveals)."""

    spec: AggSpec
    transport: str
    hosted: list[int]
    rounds: list            # rank 0's RoundResults (revealed totals)
    plan_events: list[str]  # rank 0's per-round cache events
    seconds: float
    clients_per_s: float
    latency_ms: dict
    link_totals: dict
    reorder: dict
    admission: dict
    cache: dict | None
    gateway_reports: list[dict]

    def to_doc(self) -> dict:
        return {
            "spec": self.spec.to_dict(),
            "transport": self.transport,
            "hosted": self.hosted,
            "rounds": [
                {"round": r.rnd,
                 "aggregate": [int(v) for v in r.total],
                 "survivors": r.survivors,
                 "degraded": r.degraded}
                for r in self.rounds],
            "plan_events": self.plan_events,
            "seconds": self.seconds,
            "clients_per_s": self.clients_per_s,
            "latency_ms": self.latency_ms,
            "link_totals": {
                f"{s}->{d}": {"messages": st.messages, "bytes": st.bytes}
                for (s, d), st in sorted(self.link_totals.items())},
            "reorder": {
                f"{s}->{d}": dataclasses.asdict(st)
                for (s, d), st in sorted(self.reorder.items())},
            "admission": self.admission,
            "cache": self.cache,
        }


def verify_aggregates(result: AggResult) -> None:
    """Check every revealed round against the single-process oracle over
    the SAME surviving subset (how the tests and ``--check`` assert the
    bitwise-identity criterion)."""
    import numpy as np
    for r in result.rounds:
        ref = expected_sum(result.spec, r.rnd, survivors=r.survivors)
        if not np.array_equal(np.asarray(r.total, dtype=np.uint64), ref):
            raise AssertionError(
                f"round {r.rnd}: aggregate over {len(r.survivors)} "
                f"survivors does not match the reference sum")


def _apply_depth(fabric: Fabric, spec: AggSpec) -> None:
    """Bound every gateway→server link's reorder buffer per the spec
    (backends without depth knobs — tcp — already bound link memory via
    their reader-side byte cap)."""
    if not (spec.max_inflight_msgs or spec.max_inflight_bytes):
        return
    for k in range(spec.servers):
        if k not in fabric.transports:
            continue
        t = fabric.transport_for(k)
        if not hasattr(t, "set_depth"):
            continue
        for g in range(spec.gateways):
            t.set_depth(spec.gateway_rank(g), k,
                        max_msgs=spec.max_inflight_msgs,
                        max_bytes=spec.max_inflight_bytes)


def run_aggregation(spec: AggSpec, transport: str = "inproc",
                    fabric_spec: FabricSpec | None = None,
                    cache=None, drop=None) -> AggResult:
    """Run the online phase for every endpoint hosted by this process.

    ``drop`` is an iterable of ``(round, client)`` pairs that never send
    (the straggler model).  ``cache`` is an ``ArtifactCache`` (or None);
    only server rank 0 consults it — one miss cold, zero re-plans hot.
    """
    fabric_spec = fabric_spec or FabricSpec()
    dropset = frozenset((int(r), int(c)) for r, c in (drop or ()))
    fabric = build_fabric(transport, spec.num_endpoints, fabric_spec)
    fabric.connect()
    _apply_depth(fabric, spec)

    base_plan = build_round_plan(spec)      # the offline-distributed copy
    admission = AdmissionController(
        frame_pool=spec.frame_pool,
        memory_bytes=spec.frame_pool * (64 << 10))
    latency = LatencyBook() if not fabric.distributed else None

    results: dict[int, dict] = {}
    errors: list[BaseException] = []

    def _endpoint(rank: int) -> None:
        try:
            t = fabric.transport_for(rank)
            if rank < spec.servers:
                if rank == 0:
                    loader = lambda: load_round_plan(cache, spec)  # noqa: E731
                else:
                    loader = lambda: (base_plan, "offline")        # noqa: E731
                results[rank] = run_server(t, spec, rank, admission,
                                           loader, latency=latency)
            else:
                results[rank] = run_gateway(t, spec, base_plan,
                                            rank - spec.servers,
                                            drop=dropset, latency=latency)
        except BaseException as e:  # re-raised after join
            errors.append(e)

    t0 = time.monotonic()
    threads = [threading.Thread(target=_endpoint, args=(r,), daemon=True,
                                name=f"agg-rank{r}") for r in fabric.hosted]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    seconds = time.monotonic() - t0
    if errors:
        fabric.close()
        raise errors[0]

    # hold multi-process peers open until everyone drained their rounds
    if fabric.distributed:
        fabric.barrier()

    link_totals = fabric.link_totals()
    reorder = fabric.reorder_stats()
    fabric.close()

    r0 = results.get(0, {})
    rounds = r0.get("rounds", [])
    ingested = sum(len(r.survivors) for r in rounds)
    return AggResult(
        spec=spec,
        transport=transport,
        hosted=list(fabric.hosted),
        rounds=rounds,
        plan_events=r0.get("plan_events", []),
        seconds=seconds,
        clients_per_s=(ingested / seconds) if seconds > 0 else 0.0,
        latency_ms=latency.percentiles_ms() if latency else {},
        link_totals=link_totals,
        reorder=reorder,
        admission=admission.status(),
        cache=(cache.status() if cache is not None else None),
        gateway_reports=[results[r] for r in fabric.hosted
                         if r >= spec.servers],
    )
