"""MAGE (OSDI'21) in JAX: memory programming for secure computation, built
out as a multi-pod training/serving framework.  See DESIGN.md for the map:

  repro.core        planner (placement / Belady MIN / prefetch scheduling),
                    engine, storage, timing simulator, workers, jaxpr offload
  repro.protocols   garbled circuits + CKKS drivers and DSLs
  repro.kernels     Pallas TPU kernels (garble, ntt, paged_attn)
  repro.workloads   the paper's ten workloads + §8.8 applications
  repro.models/...  the LM framework (10 assigned architectures)
  repro.launch      mesh, multi-pod dryrun, train, serve entry points
"""

__version__ = "1.0.0"

_API_NAMES = ("JobSpec", "Session", "SpecMismatchError", "run_job",
              "register_driver", "register_storage")


def __getattr__(name):
    # lazy: `import repro` stays light; `repro.JobSpec` pulls in the facade
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
