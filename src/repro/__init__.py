"""MAGE (OSDI'21) in JAX: memory programming for secure computation, built
out as a multi-pod training/serving framework.  See DESIGN.md for the map:

  repro.core        planner (placement / Belady MIN / prefetch scheduling),
                    engine, storage, timing simulator, workers, jaxpr offload
  repro.protocols   garbled circuits + CKKS drivers and DSLs
  repro.kernels     Pallas TPU kernels (garble, ntt, paged_attn)
  repro.workloads   the paper's ten workloads + §8.8 applications
  repro.models/...  the LM framework (10 assigned architectures)
  repro.launch      mesh, multi-pod dryrun, train, serve entry points

The stable public surface (docs/API.md) is re-exported here:

  JobSpec, Session, plan, run_job    the staged facade (repro.api)
  serve_client                       talk to a `python -m repro serve` daemon
  list_workloads/list_drivers/
  list_storages/list_transports      registry discovery
  SpecMismatchError, SCHEMA_VERSION, register_driver, register_storage
"""

__version__ = "1.1.0"

_API_NAMES = ("JobSpec", "Session", "SpecMismatchError", "run_job", "plan",
              "estimate_job_resources", "SCHEMA_VERSION",
              "register_driver", "register_storage",
              "list_workloads", "list_drivers", "list_storages",
              "list_transports")

_SERVE_NAMES = ("serve_client", "ServeClient")


def __getattr__(name):
    # lazy: `import repro` stays light; `repro.JobSpec` pulls in the facade
    if name in _API_NAMES:
        from . import api
        return getattr(api, name)
    if name in _SERVE_NAMES:
        from .serve_daemon import client
        return getattr(client, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_NAMES) | set(_SERVE_NAMES))
