"""Jit'd public API for the garbling kernels + uint64<->uint32 adapters.

The protocol driver stores labels as (m, 2) uint64; the TPU kernel wants
(m, 4) uint32 lanes.  ``interpret=None`` auto-selects: compiled on a real
XLA backend, interpret mode on CPU (see ``kernels.resolve_interpret``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .. import resolve_interpret
from . import kernel, ref


def u64_to_u32(lbl: np.ndarray) -> np.ndarray:
    return np.ascontiguousarray(lbl).astype("<u8").view("<u4").reshape(-1, 4)


def u32_to_u64(lbl: np.ndarray) -> np.ndarray:
    arr = np.ascontiguousarray(np.asarray(lbl))
    return arr.astype("<u4").view("<u8").reshape(-1, arr.shape[1] // 2)


def _pad(x: np.ndarray, block: int) -> tuple[np.ndarray, int]:
    m = x.shape[0]
    pad = (-m) % block
    if pad:
        x = np.concatenate([x, np.zeros((pad, *x.shape[1:]), x.dtype)])
    return x, m


def garble_and(a0_u64: np.ndarray, b0_u64: np.ndarray, r_u64: np.ndarray,
               gid0: int, *, use_kernel: bool = True,
               interpret: bool | None = None,
               block_m: int = 64) -> tuple[np.ndarray, np.ndarray]:
    """Batch half-gates garble; uint64-pair API matching the driver.

    Returns (c0 (m,2) uint64, tables (m,4) uint64)."""
    if len(a0_u64) == 0:
        # empty batch: the grid would be 0 blocks, which pallas rejects
        return (np.zeros((0, 2), dtype=np.uint64),
                np.zeros((0, 4), dtype=np.uint64))
    interpret = resolve_interpret(interpret)
    a = u64_to_u32(a0_u64)
    b = u64_to_u32(b0_u64)
    r = u64_to_u32(r_u64.reshape(1, 2))[0]
    a, m = _pad(a, block_m)
    b, _ = _pad(b, block_m)
    if use_kernel:
        c, tab = kernel.garble_and_pallas(
            jnp.asarray(a), jnp.asarray(b), jnp.asarray(r),
            jnp.int32(2 * gid0), interpret=interpret, block_m=block_m)
    else:
        c, tab = ref.garble_and(jnp.asarray(a), jnp.asarray(b),
                                jnp.asarray(r), 2 * gid0)
    return (u32_to_u64(np.asarray(c))[:m],
            u32_to_u64(np.asarray(tab))[:m])


def eval_and(wa_u64: np.ndarray, wb_u64: np.ndarray, tables_u64: np.ndarray,
             gid0: int, *, use_kernel: bool = True,
             interpret: bool | None = None,
             block_m: int = 64) -> np.ndarray:
    if len(wa_u64) == 0:
        return np.zeros((0, 2), dtype=np.uint64)
    interpret = resolve_interpret(interpret)
    wa = u64_to_u32(wa_u64)
    wb = u64_to_u32(wb_u64)
    tab = np.ascontiguousarray(tables_u64).astype("<u8").view("<u4") \
        .reshape(-1, 8)
    wa, m = _pad(wa, block_m)
    wb, _ = _pad(wb, block_m)
    tab, _ = _pad(tab, block_m)
    if use_kernel:
        c = kernel.eval_and_pallas(
            jnp.asarray(wa), jnp.asarray(wb), jnp.asarray(tab),
            jnp.int32(2 * gid0), interpret=interpret, block_m=block_m)
    else:
        c = ref.eval_and(jnp.asarray(wa), jnp.asarray(wb), jnp.asarray(tab),
                         2 * gid0)
    return u32_to_u64(np.asarray(c))[:m]
