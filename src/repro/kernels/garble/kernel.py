"""Pallas TPU kernel: batched half-gates garbling / evaluation.

TPU adaptation of the paper's fixed-key-AES hot loop (§7.3): instead of the
CPU-idiomatic table-lookup S-box (random gathers are hostile to the VPU),
SubBytes is computed as a CONSTANT-TIME GF(2^8) inversion — x^254 via an
addition chain of carry-less multiplies — all branch-free bitwise ops on
int32 lanes.  Lookup-free crypto is also oblivious at the instruction level,
which matches the paper's thesis that SC execution has data-independent
behavior.

Layout: a gate batch block of BLOCK_M gates lives in VMEM as (BLOCK_M, 4)
uint32 label tiles (a 128-bit label per row); the AES state is (4*BLOCK_M,
16) int32 — all four hashes of a half-gate are batched into ONE AES pass.
The grid streams gate blocks HBM->VMEM exactly like MAGE streams pages:
the BlockSpec index maps are the (fully static) memory program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...protocols.garbled.aes import ROUND_KEYS

BLOCK_M = 256

_RK = jnp.asarray(ROUND_KEYS.astype(np.int32))
_SHIFT_ROWS = tuple(int(x) for x in
                    [(i + 4 * (i % 4)) % 16 for i in range(16)])


# ---------------------------------------------------------------------------
# constant-time AES core (shared by both kernel bodies; pure jnp ops on
# int32 so it lowers cleanly inside Pallas)
# ---------------------------------------------------------------------------


def _gmul(a, b):
    """Carry-less GF(2^8) multiply, branch-free, int32 lanes."""
    acc = jnp.zeros_like(a)
    aa = a
    bb = b
    for _ in range(8):
        acc = acc ^ (aa * (bb & 1))
        bb = bb >> 1
        aa = ((aa << 1) ^ ((aa >> 7) & 1) * 0x1B) & 0xFF
    return acc


def _ginv(x):
    """x^254 in GF(2^8): constant-time inverse (0 -> 0)."""
    x2 = _gmul(x, x)
    x4 = _gmul(x2, x2)
    x8 = _gmul(x4, x4)
    x16 = _gmul(x8, x8)
    x32 = _gmul(x16, x16)
    x64 = _gmul(x32, x32)
    x128 = _gmul(x64, x64)
    r = _gmul(x128, x64)
    r = _gmul(r, x32)
    r = _gmul(r, x16)
    r = _gmul(r, x8)
    r = _gmul(r, x4)
    return _gmul(r, x2)


def _sbox_ct(x):
    """SubBytes: inversion + affine transform, no lookups."""
    inv = _ginv(x)
    res = 0x63
    for sh in range(5):
        rot = ((inv << sh) | (inv >> (8 - sh))) & 0xFF
        res = res ^ rot
    return res & 0xFF


def _xtime(b):
    return ((b << 1) ^ ((b >> 7) & 1) * 0x1B) & 0xFF


def _shift_rows(s):
    return jnp.concatenate([s[:, i:i + 1] for i in _SHIFT_ROWS], axis=1)


def aes128_ct(blocks, rk):
    """Constant-time AES-128 on (m, 16) int32 byte state."""
    s = blocks ^ rk[0]
    for rnd in range(1, 10):
        s = _sbox_ct(s)
        s = _shift_rows(s)
        v = s.reshape(-1, 4, 4)
        x = _xtime(v)
        r1 = jnp.roll(v, -1, axis=2)
        r2 = jnp.roll(v, -2, axis=2)
        r3 = jnp.roll(v, -3, axis=2)
        s = (x ^ r1 ^ _xtime(r1) ^ r2 ^ r3).reshape(-1, 16) ^ rk[rnd]
    s = _sbox_ct(s)
    s = _shift_rows(s)
    return s ^ rk[10]


def _to_bytes(lbl):
    l32 = lbl.astype(jnp.uint32)
    return jnp.stack(
        [((l32[:, i // 4] >> jnp.uint32(8 * (i % 4)))
          & jnp.uint32(0xFF)).astype(jnp.int32) for i in range(16)], axis=1)


def _to_labels(b):
    b = b.astype(jnp.uint32)
    return jnp.stack(
        [b[:, 4 * w] | (b[:, 4 * w + 1] << jnp.uint32(8))
         | (b[:, 4 * w + 2] << jnp.uint32(16))
         | (b[:, 4 * w + 3] << jnp.uint32(24)) for w in range(4)], axis=1)


def _double(l):
    l = l.astype(jnp.uint32)
    carry_top = l[:, 3] >> jnp.uint32(31)
    lanes = []
    prev = jnp.zeros_like(l[:, 0])
    for i in range(4):
        lanes.append((l[:, i] << jnp.uint32(1)) | prev)
        prev = l[:, i] >> jnp.uint32(31)
    lanes[0] = lanes[0] ^ (carry_top * jnp.uint32(0x87))
    return jnp.stack(lanes, axis=1)


def _hash4(labels, gids, rk):
    """One batched constant-time AES pass hashing (m, 4)-label array with
    per-row tweaks ``gids`` (int32)."""
    y = _double(labels)
    y = y.at[:, 0].set(y[:, 0] ^ gids.astype(jnp.uint32))
    enc = aes128_ct(_to_bytes(y), rk)
    return _to_labels(enc) ^ y


def _mask(bits, lbl):
    return jnp.where((bits != 0)[:, None], lbl, jnp.uint32(0))


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------


def _garble_kernel(a_ref, b_ref, r_ref, gid_ref, rk_ref, c_ref, tab_ref):
    m = a_ref.shape[0]
    a0 = a_ref[...]
    b0 = b_ref[...]
    r = r_ref[...]
    rr = jnp.broadcast_to(r.reshape(1, 4), (m, 4))
    base = gid_ref[0]
    j0 = base + 2 * jax.lax.iota(jnp.int32, m)
    j1 = j0 + 1
    # all four hashes in ONE AES pass: rows [A0 | A1 | B0 | B1]
    stacked = jnp.concatenate([a0, a0 ^ rr, b0, b0 ^ rr], axis=0)
    gids = jnp.concatenate([j0, j0, j1, j1], axis=0)
    h = _hash4(stacked, gids, rk_ref[...])
    ha0, ha1, hb0, hb1 = h[:m], h[m:2 * m], h[2 * m:3 * m], h[3 * m:]
    pa = a0[:, 0] & jnp.uint32(1)
    pb = b0[:, 0] & jnp.uint32(1)
    tg = ha0 ^ ha1 ^ _mask(pb, rr)
    wg = ha0 ^ _mask(pa, tg)
    te = hb0 ^ hb1 ^ a0
    we = hb0 ^ _mask(pb, te ^ a0)
    c_ref[...] = wg ^ we
    tab_ref[...] = jnp.concatenate([tg, te], axis=1)


def _eval_kernel(a_ref, b_ref, tab_ref, gid_ref, rk_ref, c_ref):
    m = a_ref.shape[0]
    wa = a_ref[...]
    wb = b_ref[...]
    tab = tab_ref[...]
    base = gid_ref[0]
    j0 = base + 2 * jax.lax.iota(jnp.int32, m)
    j1 = j0 + 1
    stacked = jnp.concatenate([wa, wb], axis=0)
    gids = jnp.concatenate([j0, j1], axis=0)
    h = _hash4(stacked, gids, rk_ref[...])
    hwa, hwb = h[:m], h[m:]
    sa = wa[:, 0] & jnp.uint32(1)
    sb = wb[:, 0] & jnp.uint32(1)
    tg, te = tab[:, :4], tab[:, 4:]
    wg = hwa ^ _mask(sa, tg)
    we = hwb ^ _mask(sb, te ^ wa)
    c_ref[...] = wg ^ we


# ---------------------------------------------------------------------------
# pallas_call wrappers (grid over gate blocks)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def garble_and_pallas(a0, b0, r, gid0, *, interpret: bool = True,
                      block_m: int = BLOCK_M):
    m = a0.shape[0]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    gid_blocks = (gid0 + 2 * block_m *
                  jnp.arange(grid[0], dtype=jnp.int32)).reshape(-1, 1)
    return pl.pallas_call(
        _garble_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 8), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, 4), jnp.uint32),
            jax.ShapeDtypeStruct((m, 8), jnp.uint32),
        ],
        interpret=interpret,
    )(a0, b0, r.reshape(1, 4), gid_blocks, _RK)


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def eval_and_pallas(wa, wb, tables, gid0, *, interpret: bool = True,
                    block_m: int = BLOCK_M):
    m = wa.shape[0]
    assert m % block_m == 0, (m, block_m)
    grid = (m // block_m,)
    gid_blocks = (gid0 + 2 * block_m *
                  jnp.arange(grid[0], dtype=jnp.int32)).reshape(-1, 1)
    return pl.pallas_call(
        _eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 8), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 4), jnp.uint32),
        interpret=interpret,
    )(wa, wb, tables, gid_blocks, _RK)
