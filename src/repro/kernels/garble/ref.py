"""Pure-jnp oracle for the half-gates garbling kernel.

Table-based AES-128 (S-box via jnp.take) over uint32-packed labels — an
independent implementation path from the Pallas kernel's constant-time
GF(2^8)-inversion S-box.  Both must agree bit-exactly with each other and
with the numpy driver implementation (protocols/garbled/aes.py), which is
itself checked against the FIPS-197 vector.

Label layout here is (m, 4) uint32 little-endian (lane 0 = bits 0..31).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ...protocols.garbled.aes import ROUND_KEYS, SBOX, SHIFT_ROWS

_SBOX = jnp.asarray(SBOX, dtype=jnp.int32)
_SHIFT_ROWS = jnp.asarray(SHIFT_ROWS, dtype=jnp.int32)
# round keys as (11, 16) int32 byte values
_RK = jnp.asarray(ROUND_KEYS.astype(np.int32))


def labels_to_bytes(lbl: jnp.ndarray) -> jnp.ndarray:
    """(m, 4) uint32 -> (m, 16) int32 bytes, little-endian."""
    l32 = lbl.astype(jnp.uint32)
    parts = [((l32[:, i // 4] >> jnp.uint32(8 * (i % 4)))
              & jnp.uint32(0xFF)).astype(jnp.int32) for i in range(16)]
    return jnp.stack(parts, axis=1)


def bytes_to_labels(b: jnp.ndarray) -> jnp.ndarray:
    """(m, 16) int32 bytes -> (m, 4) uint32."""
    b = b.astype(jnp.uint32)
    lanes = []
    for w in range(4):
        lane = (b[:, 4 * w] | (b[:, 4 * w + 1] << jnp.uint32(8))
                | (b[:, 4 * w + 2] << jnp.uint32(16))
                | (b[:, 4 * w + 3] << jnp.uint32(24)))
        lanes.append(lane)
    return jnp.stack(lanes, axis=1)


def _xtime(b: jnp.ndarray) -> jnp.ndarray:
    return ((b << 1) ^ jnp.where(b & 0x80 != 0, 0x1B, 0)) & 0xFF


def aes128(blocks: jnp.ndarray) -> jnp.ndarray:
    """(m, 16) int32 byte state -> encrypted (m, 16) int32."""
    s = blocks ^ _RK[0]
    for rnd in range(1, 10):
        s = jnp.take(_SBOX, s, axis=0)
        s = s[:, _SHIFT_ROWS]
        v = s.reshape(-1, 4, 4)
        x = _xtime(v)
        r1 = jnp.roll(v, -1, axis=2)
        r2 = jnp.roll(v, -2, axis=2)
        r3 = jnp.roll(v, -3, axis=2)
        s = (x ^ r1 ^ _xtime(r1) ^ r2 ^ r3).reshape(-1, 16) ^ _RK[rnd]
    s = jnp.take(_SBOX, s, axis=0)
    s = s[:, _SHIFT_ROWS]
    return s ^ _RK[10]


def gf128_double(lbl: jnp.ndarray) -> jnp.ndarray:
    """x -> 2x in GF(2^128), (m, 4) uint32 little-endian lanes."""
    l = lbl.astype(jnp.uint32)
    carry_top = l[:, 3] >> jnp.uint32(31)
    out = []
    prev = jnp.zeros_like(l[:, 0])
    for i in range(4):
        cur = (l[:, i] << jnp.uint32(1)) | prev
        prev = l[:, i] >> jnp.uint32(31)
        out.append(cur)
    out[0] = out[0] ^ (carry_top * jnp.uint32(0x87))
    return jnp.stack(out, axis=1)


def hash_labels(lbl: jnp.ndarray, gate_ids: jnp.ndarray) -> jnp.ndarray:
    """H(x, i) = AES_k(2x ^ i) ^ (2x ^ i); gate_ids (m,) int32 -> lane 0."""
    y = gf128_double(lbl)
    y = y.at[:, 0].set(y[:, 0] ^ gate_ids.astype(jnp.uint32))
    enc = aes128(labels_to_bytes(y))
    return bytes_to_labels(enc) ^ y


def _maskw(bits: jnp.ndarray, lbl: jnp.ndarray) -> jnp.ndarray:
    return jnp.where((bits != 0)[:, None], lbl, jnp.uint32(0))


def garble_and(a0: jnp.ndarray, b0: jnp.ndarray, r: jnp.ndarray,
               gid0: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Half-gates garbling (ZRE15).  a0/b0: (m,4) uint32 zero labels;
    r: (4,) global offset.  Returns (c0 (m,4), tables (m,8) [TG|TE])."""
    m = a0.shape[0]
    j0 = gid0 + 2 * jnp.arange(m, dtype=jnp.int32)
    j1 = j0 + 1
    pa = a0[:, 0] & jnp.uint32(1)
    pb = b0[:, 0] & jnp.uint32(1)
    rr = jnp.broadcast_to(r, (m, 4))
    ha0 = hash_labels(a0, j0)
    ha1 = hash_labels(a0 ^ rr, j0)
    hb0 = hash_labels(b0, j1)
    hb1 = hash_labels(b0 ^ rr, j1)
    tg = ha0 ^ ha1 ^ _maskw(pb, rr)
    wg = ha0 ^ _maskw(pa, tg)
    te = hb0 ^ hb1 ^ a0
    we = hb0 ^ _maskw(pb, te ^ a0)
    return wg ^ we, jnp.concatenate([tg, te], axis=1)


def eval_and(wa: jnp.ndarray, wb: jnp.ndarray, tables: jnp.ndarray,
             gid0: int) -> jnp.ndarray:
    """Half-gates evaluation: active labels + tables -> active out label."""
    m = wa.shape[0]
    j0 = gid0 + 2 * jnp.arange(m, dtype=jnp.int32)
    j1 = j0 + 1
    sa = wa[:, 0] & jnp.uint32(1)
    sb = wb[:, 0] & jnp.uint32(1)
    tg, te = tables[:, :4], tables[:, 4:]
    wg = hash_labels(wa, j0) ^ _maskw(sa, tg)
    we = hash_labels(wb, j1) ^ _maskw(sb, te ^ wa)
    return wg ^ we
