"""Pure-jnp oracle for paged decode attention.

Gathers KV pages through the block table into dense (batch, seq, kv_heads,
head_dim) tensors and runs masked GQA attention for one decode step.
"""

from __future__ import annotations

import jax  # noqa: F401  (kept for parity with kernel imports)
import jax.numpy as jnp
import numpy as np


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens):
    """q: (batch, q_heads, head_dim); k_pages/v_pages: (num_pages, page_sz,
    kv_heads, head_dim); block_table: (batch, max_pages) int32; seq_lens:
    (batch,) int32.  Returns (batch, q_heads, head_dim) float32."""
    q = jnp.asarray(q, dtype=jnp.float32)
    k_pages = jnp.asarray(k_pages, dtype=jnp.float32)
    v_pages = jnp.asarray(v_pages, dtype=jnp.float32)
    batch, q_heads, head_dim = q.shape
    num_pages, page_sz, kv_heads, _ = k_pages.shape
    max_pages = block_table.shape[1]
    group = q_heads // kv_heads

    # gather pages -> (batch, max_pages*page_sz, kv_heads, head_dim)
    k = k_pages[block_table].reshape(batch, max_pages * page_sz,
                                     kv_heads, head_dim)
    v = v_pages[block_table].reshape(batch, max_pages * page_sz,
                                     kv_heads, head_dim)
    qg = q.reshape(batch, kv_heads, group, head_dim)
    scale = 1.0 / np.sqrt(head_dim)
    # scores: (batch, kv_heads, group, seq)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k) * scale
    pos = jnp.arange(max_pages * page_sz)[None, :]
    mask = pos < jnp.asarray(seq_lens)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return out.reshape(batch, q_heads, head_dim)
