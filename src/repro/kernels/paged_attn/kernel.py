"""Pallas TPU kernel: paged decode attention (flash-decoding over a block
table).

This is MAGE's paged-KV memory program realized at the kernel level
(DESIGN.md §4): the page schedule (block table) is known before the kernel
runs — decode's access pattern is oblivious — so pages are *scalar-
prefetched* and streamed HBM->VMEM with no data-dependent stalls, the exact
analogue of ISSUE-SWAP-IN / FINISH-SWAP-IN with lookahead.

Grid: (batch, kv_heads, max_pages); the block table and sequence lengths
ride in scalar-prefetch SMEM so the K/V BlockSpec index maps can resolve
page -> HBM tile before each step.  Online softmax state (m, l, acc) lives
in VMEM scratch across the page loop; the output block is written on the
last page step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_sz: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (group, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (page_sz, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = p * page_sz + jax.lax.iota(jnp.int32, page_sz)
    valid = pos < len_ref[b]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]                           # (group, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    pexp = jnp.exp(s - m_new)                     # (group, page_sz)
    l_new = l_ref[...] * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        pexp, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(p == n_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q, k_pages, v_pages, block_table,
                                  seq_lens, *, interpret: bool = True):
    """q: (batch, kv_heads, group, head_dim); k_pages/v_pages: (num_pages,
    page_sz, kv_heads, head_dim); block_table (batch, max_pages) int32;
    seq_lens (batch,) int32 -> (batch, kv_heads, group, head_dim) f32."""
    batch, kv_heads, group, head_dim = q.shape
    num_pages, page_sz, _, _ = k_pages.shape
    max_pages = block_table.shape[1]
    scale = 1.0 / float(head_dim) ** 0.5

    def q_map(b, h, p, bt, sl):
        return (b, h, 0, 0)

    def kv_map(b, h, p, bt, sl):
        return (bt[b, p], 0, h, 0)

    def o_map(b, h, p, bt, sl):
        return (b, h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(batch, kv_heads, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, group, head_dim), q_map),
            pl.BlockSpec((1, page_sz, 1, head_dim), kv_map),
            pl.BlockSpec((1, page_sz, 1, head_dim), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, head_dim), o_map),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, head_dim), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page_sz=page_sz, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((batch, kv_heads, group, head_dim),
                                       jnp.float32),
        interpret=interpret,
    )(block_table, seq_lens, q, k_pages, v_pages)
