"""Jit'd public API for paged decode attention (GQA layout adapter)."""

from __future__ import annotations

import jax.numpy as jnp

from .. import resolve_interpret
from . import kernel, ref


def paged_decode_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                           use_kernel: bool = True,
                           interpret: bool | None = None):
    """q: (batch, q_heads, head_dim) -> (batch, q_heads, head_dim) f32."""
    interpret = resolve_interpret(interpret)
    batch, q_heads, head_dim = q.shape
    kv_heads = k_pages.shape[2]
    group = q_heads // kv_heads
    if not use_kernel:
        return ref.paged_decode_attention(q, k_pages, v_pages, block_table,
                                          seq_lens)
    qg = jnp.asarray(q).reshape(batch, kv_heads, group, head_dim)
    out = kernel.paged_decode_attention_pallas(
        qg, jnp.asarray(k_pages), jnp.asarray(v_pages),
        jnp.asarray(block_table, dtype=jnp.int32),
        jnp.asarray(seq_lens, dtype=jnp.int32), interpret=interpret)
    return out.reshape(batch, q_heads, head_dim)
