"""Pallas TPU kernels for the paper's compute hot-spots (+ the serving tie-in):

  garble/     batched half-gates garbling/evaluation with constant-time
              (lookup-free) AES — the fixed-key AES hot loop of §7.3
  ntt/        negacyclic NTT for CKKS polynomial arithmetic, 32-bit-limb
              Barrett modmul (no native 64-bit multiplies needed)
  paged_attn/ flash-decoding over a scalar-prefetched block table — MAGE's
              paged-KV memory program at the kernel level

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public API + layout adapters), ref.py (pure-jnp oracle).  Validated in
interpret mode on CPU; TPU is the lowering target.

Interpret-mode selection: compiled ``pallas_call`` cannot lower on the CPU
backend, so every ops.py entry point defaults ``interpret=None`` and
resolves it through :func:`resolve_interpret` — compiled when a real XLA
accelerator backend is present, interpret otherwise.  Setting
``REPRO_PALLAS_INTERPRET=1`` forces interpret mode everywhere (the escape
hatch for debugging kernels on accelerator hosts).
"""

from __future__ import annotations

import functools
import os


@functools.lru_cache(maxsize=1)
def _default_backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax import/init failure
        return "cpu"


def use_pallas() -> bool:
    """True when compiled ``pallas_call`` can actually lower here: a
    non-CPU XLA backend is present and the escape hatch is not set."""
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return False
    return _default_backend() != "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> auto (compiled iff a real backend is present); an
    explicit bool is honored as-is."""
    return (not use_pallas()) if interpret is None else interpret


from . import garble, ntt, paged_attn  # noqa: E402

__all__ = ["garble", "ntt", "paged_attn", "resolve_interpret", "use_pallas"]
