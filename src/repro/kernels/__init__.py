"""Pallas TPU kernels for the paper's compute hot-spots (+ the serving tie-in):

  garble/     batched half-gates garbling/evaluation with constant-time
              (lookup-free) AES — the fixed-key AES hot loop of §7.3
  ntt/        negacyclic NTT for CKKS polynomial arithmetic, 32-bit-limb
              Barrett modmul (no native 64-bit multiplies needed)
  paged_attn/ flash-decoding over a scalar-prefetched block table — MAGE's
              paged-KV memory program at the kernel level

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
public API + layout adapters), ref.py (pure-jnp oracle).  Validated in
interpret mode on CPU; TPU is the lowering target.
"""

from . import garble, ntt, paged_attn

__all__ = ["garble", "ntt", "paged_attn"]
