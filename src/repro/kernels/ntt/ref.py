"""Pure-jnp oracle for the NTT kernel: uint64 modular arithmetic, same
Longa–Naehrig stage schedule as protocols/ckks/ntt.py (the numpy engine
path) — all three implementations must agree exactly."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ...protocols.ckks.ntt import ntt_tables


def ntt_forward(a, q: int, psis_brv: np.ndarray):
    """a: (..., N) uint64 standard order -> bit-reversed NTT domain."""
    with jax.experimental.enable_x64():
        a = jnp.asarray(np.asarray(a))
        n = a.shape[-1]
        qq = jnp.uint64(q)
        psis = jnp.asarray(psis_brv, dtype=jnp.uint64)
        v = a.astype(jnp.uint64)
        lead = v.shape[:-1]
        t = n
        m = 1
        while m < n:
            t //= 2
            w = v.reshape(*lead, m, 2, t)
            s = psis[m:2 * m].reshape((1,) * len(lead) + (m, 1))
            u = w[..., 0, :]
            x = (w[..., 1, :] * s) % qq
            v = jnp.stack([(u + x) % qq, (u + qq - x) % qq],
                          axis=-2).reshape(*lead, n)
            m *= 2
        return v


def ntt_inverse(a, q: int, psis_inv_brv: np.ndarray, n_inv: int):
    with jax.experimental.enable_x64():
        a = jnp.asarray(np.asarray(a))
        n = a.shape[-1]
        qq = jnp.uint64(q)
        psis = jnp.asarray(psis_inv_brv, dtype=jnp.uint64)
        v = a.astype(jnp.uint64)
        lead = v.shape[:-1]
        t = 1
        m = n
        while m > 1:
            h = m // 2
            w = v.reshape(*lead, h, 2, t)
            s = psis[h:2 * h].reshape((1,) * len(lead) + (h, 1))
            u = w[..., 0, :]
            x = w[..., 1, :]
            v = jnp.stack([(u + x) % qq, ((u + qq - x) % qq * s) % qq],
                          axis=-2).reshape(*lead, n)
            t *= 2
            m = h
        return (v * jnp.uint64(n_inv)) % qq


def pointwise_mul(a, b, q: int):
    with jax.experimental.enable_x64():
        a = jnp.asarray(np.asarray(a))
        b = jnp.asarray(np.asarray(b))
        return (a.astype(jnp.uint64) * b.astype(jnp.uint64)) % jnp.uint64(q)


def tables(q: int, n: int):
    return ntt_tables(q, n)
