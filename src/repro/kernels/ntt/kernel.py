"""Pallas TPU kernel: negacyclic NTT for CKKS polynomial arithmetic.

TPU adaptation (DESIGN.md §3): the modular multiply is built from 32-bit
lanes only — 16-bit limb products composed into a 64-bit (hi, lo) pair and
reduced with parameterized Barrett (mu = floor(2^2k / q), k = bitlen(q)),
so nothing needs native 64-bit multiplies.  Primes are < 2^30 (the chain
primes of protocols/ckks/params.py satisfy this).

Blocking: the grid runs over batches of polynomials; each kernel instance
holds a (BLOCK_B, N) uint32 tile plus the (N,) twiddle table in VMEM and
executes all log2(N) Longa–Naehrig stages in-register — for N <= 8192 and
BLOCK_B = 8 that is < 300 KiB of VMEM.  Stage reshapes are static, so the
whole butterfly schedule is known at compile time: the BlockSpec grid is
the memory program for streaming the polynomial batch HBM -> VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 8


def _mul64(a, b):
    """uint32 x uint32 -> 64-bit (hi, lo) via 16-bit limbs (TPU-native)."""
    a0 = a & jnp.uint32(0xFFFF)
    a1 = a >> jnp.uint32(16)
    b0 = b & jnp.uint32(0xFFFF)
    b1 = b >> jnp.uint32(16)
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    mid = lh + hl                      # < 2^32, no wrap for a,b < 2^31
    lo = ll + ((mid & jnp.uint32(0xFFFF)) << jnp.uint32(16))
    carry = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> jnp.uint32(16)) + carry
    return hi, lo


def _modmul(a, b, q: int, mu: int, k: int):
    """a*b mod q with Barrett; q < 2^30, k = q.bit_length(), static."""
    x_hi, x_lo = _mul64(a, b)
    t1 = (x_hi << jnp.uint32(32 - (k - 1))) | (x_lo >> jnp.uint32(k - 1))
    p_hi, p_lo = _mul64(t1, jnp.uint32(mu))
    qest = (p_hi << jnp.uint32(32 - (k + 1))) | (p_lo >> jnp.uint32(k + 1))
    _, qq_lo = _mul64(qest, jnp.uint32(q))
    r = x_lo - qq_lo                   # exact in low 32 bits (r < 3q < 2^32)
    r = jnp.where(r >= jnp.uint32(q), r - jnp.uint32(q), r)
    r = jnp.where(r >= jnp.uint32(q), r - jnp.uint32(q), r)
    return r


def _addmod(a, b, q: int):
    s = a + b
    return jnp.where(s >= jnp.uint32(q), s - jnp.uint32(q), s)


def _submod(a, b, q: int):
    return jnp.where(a >= b, a - b, a + jnp.uint32(q) - b)


def _ntt_fwd_kernel(a_ref, psi_ref, o_ref, *, q: int, mu: int, k: int,
                    n: int):
    v = a_ref[...]                      # (B, N) uint32
    psis = psi_ref[...]                 # (1, N)
    bsz = v.shape[0]
    t = n
    m = 1
    while m < n:
        t //= 2
        w = v.reshape(bsz, m, 2, t)
        s = jax.lax.dynamic_slice(psis, (0, m), (1, m)).reshape(1, m, 1)
        u = w[:, :, 0, :]
        x = _modmul(w[:, :, 1, :], jnp.broadcast_to(s, (bsz, m, t)), q, mu, k)
        v = jnp.stack([_addmod(u, x, q), _submod(u, x, q)],
                      axis=2).reshape(bsz, n)
        m *= 2
    o_ref[...] = v


def _ntt_inv_kernel(a_ref, psi_ref, o_ref, *, q: int, mu: int, k: int,
                    n: int, n_inv: int):
    v = a_ref[...]
    psis = psi_ref[...]
    bsz = v.shape[0]
    t = 1
    m = n
    while m > 1:
        h = m // 2
        w = v.reshape(bsz, h, 2, t)
        s = jax.lax.dynamic_slice(psis, (0, h), (1, h)).reshape(1, h, 1)
        u = w[:, :, 0, :]
        x = w[:, :, 1, :]
        lo = _addmod(u, x, q)
        hi = _modmul(_submod(u, x, q), jnp.broadcast_to(s, (bsz, h, t)),
                     q, mu, k)
        v = jnp.stack([lo, hi], axis=2).reshape(bsz, n)
        t *= 2
        m = h
    o_ref[...] = _modmul(v, jnp.full_like(v, jnp.uint32(n_inv)), q, mu, k)


def _pointwise_kernel(a_ref, b_ref, o_ref, *, q: int, mu: int, k: int):
    o_ref[...] = _modmul(a_ref[...], b_ref[...], q, mu, k)


def _barrett_consts(q: int) -> tuple[int, int]:
    k = q.bit_length()
    assert q < (1 << 30), "kernel Barrett path needs q < 2^30"
    return (1 << (2 * k)) // q, k


@functools.partial(jax.jit,
                   static_argnames=("q", "inverse", "n_inv", "interpret",
                                    "block_b"))
def ntt_pallas(a, psis_brv, *, q: int, inverse: bool = False, n_inv: int = 0,
               interpret: bool = True, block_b: int = BLOCK_B):
    """Batched negacyclic NTT: a is (B, N) uint32, psis_brv (N,) uint32."""
    bsz, n = a.shape
    assert bsz % block_b == 0, (bsz, block_b)
    mu, k = _barrett_consts(q)
    if inverse:
        body = functools.partial(_ntt_inv_kernel, q=q, mu=mu, k=k, n=n,
                                 n_inv=n_inv)
    else:
        body = functools.partial(_ntt_fwd_kernel, q=q, mu=mu, k=k, n=n)
    return pl.pallas_call(
        body,
        grid=(bsz // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.uint32),
        interpret=interpret,
    )(a, psis_brv.reshape(1, n))


@functools.partial(jax.jit, static_argnames=("q", "interpret", "block_b"))
def pointwise_mul_pallas(a, b, *, q: int, interpret: bool = True,
                         block_b: int = BLOCK_B):
    bsz, n = a.shape
    assert bsz % block_b == 0
    mu, k = _barrett_consts(q)
    return pl.pallas_call(
        functools.partial(_pointwise_kernel, q=q, mu=mu, k=k),
        grid=(bsz // block_b,),
        in_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0)),
                  pl.BlockSpec((block_b, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n), jnp.uint32),
        interpret=interpret,
    )(a, b)
