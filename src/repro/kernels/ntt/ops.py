"""Jit'd public API for the NTT kernel: uint64 driver layout adapters.

The CKKS driver keeps polynomials as uint64 (numpy hot path); the TPU
kernel wants uint32 (q < 2^30 so coefficients fit).  Tables come from the
shared protocols/ckks/ntt.py cache, so all three implementations use the
same twiddle ordering.
"""

from __future__ import annotations

import numpy as np

from .. import resolve_interpret
from . import kernel
from ...protocols.ckks.ntt import ntt_tables


def _pad(a: np.ndarray, block: int) -> tuple[np.ndarray, int]:
    b = a.shape[0]
    pad = (-b) % block
    if pad:
        a = np.concatenate([a, np.zeros((pad, a.shape[1]), a.dtype)])
    return a, b


def ntt_forward(a_u64: np.ndarray, q: int, *, interpret: bool | None = None,
                block_b: int = 8) -> np.ndarray:
    """(B, N) uint64 coefficients -> bit-reversed NTT domain, via Pallas."""
    interpret = resolve_interpret(interpret)
    psis, _, _ = ntt_tables(q, a_u64.shape[-1])
    a32, b = _pad(a_u64.astype(np.uint32), block_b)
    out = kernel.ntt_pallas(a32, psis.astype(np.uint32), q=q,
                            interpret=interpret, block_b=block_b)
    return np.asarray(out)[:b].astype(np.uint64)


def ntt_inverse(a_u64: np.ndarray, q: int, *, interpret: bool | None = None,
                block_b: int = 8) -> np.ndarray:
    interpret = resolve_interpret(interpret)
    n = a_u64.shape[-1]
    _, psis_inv, n_inv = ntt_tables(q, n)
    a32, b = _pad(a_u64.astype(np.uint32), block_b)
    out = kernel.ntt_pallas(a32, psis_inv.astype(np.uint32), q=q,
                            inverse=True, n_inv=int(n_inv),
                            interpret=interpret, block_b=block_b)
    return np.asarray(out)[:b].astype(np.uint64)


def negacyclic_mul(a_u64: np.ndarray, b_u64: np.ndarray, q: int, *,
                   interpret: bool | None = None,
                   block_b: int = 8) -> np.ndarray:
    """Full polynomial multiply through the kernel path."""
    interpret = resolve_interpret(interpret)
    fa = ntt_forward(a_u64, q, interpret=interpret, block_b=block_b)
    fb = ntt_forward(b_u64, q, interpret=interpret, block_b=block_b)
    fa32, bb = _pad(fa.astype(np.uint32), block_b)
    fb32, _ = _pad(fb.astype(np.uint32), block_b)
    prod = kernel.pointwise_mul_pallas(fa32, fb32, q=q, interpret=interpret,
                                       block_b=block_b)
    return ntt_inverse(np.asarray(prod)[:bb].astype(np.uint64), q,
                       interpret=interpret, block_b=block_b)
